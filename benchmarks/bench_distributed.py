"""Routed vs broadcast dist_probe on a real (forced-host) 8-device mesh.

The paper's network argument says MAPSIN ships ONLY probe keys and ONLY
matching tuples; the point-to-point a2a dispatch (core/distributed.py,
DESIGN.md §2) additionally ships each probe only to the region(s) its
range intersects — O(B) on the key leg instead of the broadcast's O(S·B).
This suite MEASURES that claim instead of modeling it:

  * wall time of ``execute_sharded`` per query under routing="broadcast"
    and routing="a2a" on an 8-shard store over 8 host devices
    (``XLA_FLAGS=--xla_force_host_platform_device_count=8``; the flag is
    applied in a subprocess so the caller's device view is untouched);
  * probe bytes from the measured probe→region fan-out ("deliveries",
    recorded by the instrumented executor with route_shards == the mesh
    size, so ``query_traffic_actual`` uses the measured branch, not the
    broadcast-equivalent fallback);
  * the static collective payloads both routings actually ship (padded
    buffers — the SPMD emulation's wire format).

Every query is also checked bit-identical between the two routings
(rows_set equality) before its timings are reported — a routing that
drops probes would fail loudly here, not skew the numbers.

Writes ``BENCH_distributed.json`` (via benchmarks.run.run_suite) when run
as ``python -m benchmarks.bench_distributed``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

NUM_SHARDS = 8
LUBM_QUERIES = ("Q1", "Q4", "Q7", "Q14")
SP2B_QUERIES = ("Q3a", "Q10")


def _mesh_main(emit=print, lubm_queries=LUBM_QUERIES,
               sp2b_queries=SP2B_QUERIES, repeats: int = 3):
    """Body that runs INSIDE the 8-device process."""
    import jax
    import numpy as np
    from jax.sharding import Mesh

    from repro.core import Caps, ExecConfig, build_store, execute_local
    from repro.core.bgp import (compile_plan, execute_sharded,
                                query_traffic_actual, rows_set)
    from repro.data import lubm_like, sp2b_like

    assert jax.device_count() >= NUM_SHARDS, jax.devices()
    mesh = Mesh(np.array(jax.devices()[:NUM_SHARDS]), ("data",))
    caps = Caps(scan_cap=1 << 14, out_cap=1 << 12, probe_cap=64,
                row_cap=64, bucket_cap=1 << 11)

    def timed(fn):
        jax.block_until_ready(fn())                     # compile
        ts = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            ts.append(time.perf_counter() - t0)
        return min(ts)

    def payload_bytes(plan, routing: str) -> int:
        """Static bytes one shard ships per execution through the probe
        collectives (the padded buffers all_gather/all_to_all move), from
        the plan's OWN embedded per-step caps. One convention for both
        routings: the local block — the all_to_all diagonal / this shard's
        own all_gather contribution / the psum_scatter chunk that stays
        home — never crosses the network and is excluded everywhere."""
        from repro.core.distributed import auto_bucket_cap
        s = NUM_SHARDS
        total = 0
        for st in plan.steps:
            if st.kind == "scan":
                continue
            b = st.caps.out_cap
            cap = (st.caps.row_cap if st.kind == "multiway"
                   else st.caps.probe_cap)
            if routing == "a2a":
                from repro.core.bgp import a2a_step_payload_bytes
                bc = st.caps.a2a_bucket_cap or auto_bucket_cap(b, s)
                total += a2a_step_payload_bytes(bc, cap, s)
            else:
                rec = (s - 1) * b * (8 + 8 + 24)        # all_gather probes
                cnts = (s - 1) * s * b * 4              # all_gather counts
                back = (s - 1) * b * cap * 8            # psum_scatter ring
                total += rec + cnts + back
        return total

    for bench, gen, queries in (("lubm", lubm_like, lubm_queries),
                                ("sp2b", sp2b_like, sp2b_queries)):
        arg = 1 if bench == "lubm" else 2000
        tr, d, qs = gen(arg)
        store = build_store(tr, num_shards=NUM_SHARDS)
        local_store = build_store(tr, num_shards=1)
        for qname in queries:
            pats = qs[qname]
            res, rows, plans = {}, {}, {}
            for routing in ("broadcast", "a2a"):
                rcfg = ExecConfig(routing=routing)
                t, v, ovf, vars_ = execute_sharded(store, pats, mesh,
                                                   "mapsin", rcfg, caps=caps)
                rows[routing] = rows_set(t, v, len(vars_))
                res[routing] = timed(lambda c=rcfg: execute_sharded(
                    store, pats, mesh, "mapsin", c, caps=caps))
                res[routing + "_ovf"] = int(np.asarray(ovf).sum())
                plans[routing] = compile_plan(store, pats, caps,
                                              routing=routing,
                                              num_shards=NUM_SHARDS)
            assert rows["a2a"] == rows["broadcast"], \
                f"{bench}/{qname}: a2a != broadcast ({len(rows['a2a'])} vs " \
                f"{len(rows['broadcast'])} rows)"
            # measured fan-out -> measured routed bytes (route_shards == mesh)
            stats: list = []
            execute_local(local_store, pats, "mapsin", caps=caps,
                          stats=stats, route_shards=NUM_SHARDS)
            routed = query_traffic_actual(stats, "mapsin_routed", NUM_SHARDS,
                                          local_store.n_triples)
            emit(f"bench_distributed/{bench}_{qname},"
                 f"{res['a2a'] * 1e6:.0f},"
                 f"a2a_us={res['a2a'] * 1e6:.0f};"
                 f"broadcast_us={res['broadcast'] * 1e6:.0f};"
                 f"time_ratio={res['broadcast'] / max(res['a2a'], 1e-9):.2f};"
                 f"probe_bytes_routed={routed['probe_bytes_routed']};"
                 f"probe_bytes_broadcast={routed['probe_bytes_broadcast']};"
                 f"net_routed={routed['network']};"
                 f"payload_a2a={payload_bytes(plans['a2a'], 'a2a')};"
                 f"payload_broadcast="
                 f"{payload_bytes(plans['broadcast'], 'broadcast')};"
                 f"rows={len(rows['a2a'])};"
                 f"identical=1;ovf={res['a2a_ovf']}")


def main(emit=print, lubm_queries=LUBM_QUERIES, sp2b_queries=SP2B_QUERIES,
         repeats: int = 3):
    """Relaunch in a subprocess with 8 forced host devices when the current
    process doesn't have them (the device-count flag must never leak into
    the caller's jax); otherwise run in place."""
    import jax
    if jax.device_count() >= NUM_SHARDS:
        return _mesh_main(emit, lubm_queries, sp2b_queries, repeats)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={NUM_SHARDS}"
                        ).strip()
    env["JAX_PLATFORMS"] = "cpu"   # the flag only forces the HOST platform
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..", "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    spec = json.dumps({"lubm": list(lubm_queries), "sp2b": list(sp2b_queries),
                       "repeats": repeats})
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_distributed", spec],
        env=env, capture_output=True, text=True,
        cwd=os.path.join(os.path.dirname(os.path.abspath(__file__)), ".."))
    if out.returncode != 0:
        raise RuntimeError(f"bench_distributed subprocess failed:\n"
                           f"{out.stderr[-4000:]}")
    for line in out.stdout.splitlines():
        if line.startswith("bench_distributed/"):
            emit(line)


if __name__ == "__main__":
    args = sys.argv[1:]
    if args and args[0].startswith("{"):
        spec = json.loads(args[0])
        import jax
        if jax.device_count() < NUM_SHARDS:      # spec arg == we ARE the
            raise SystemExit(                    # child; never respawn
                f"forced host devices ineffective: {jax.devices()}")
        _mesh_main(print, tuple(spec["lubm"]), tuple(spec["sp2b"]),
                   spec["repeats"])
    else:
        from benchmarks.run import run_suite
        import benchmarks.bench_distributed as mod
        run_suite("distributed", mod)
