"""Serving throughput: batched ServeEngine vs the sequential loop.

The paper's workload IS query serving; this harness measures the layer
PR 3 adds on top of the probe engine. An open-loop Poisson stream of
mixed LUBM + SP²Bench queries (each a template with randomized
constants — the many-tenant shape a production front door sees) runs
through two tenants' ServeEngines (shape-bucketing batcher) and through
the sequential one-query-at-a-time `execute_local` loop, on a virtual
clock driven by measured wall times:

  saturated — all requests queued, drained at max_batch: the raw
              queries/sec capacity comparison (the >= 3x acceptance
              gate, recorded as `speedup`), avg batch >= 8;
  poisson   — arrivals at 1.5x the sequential engine's measured
              capacity: p50/p99 latency at a load the sequential loop
              cannot sustain (its queue grows all run) while the
              batcher absorbs it with moderate batches;
  coldstart — first-contact cost: the sequential loop compiles one
              cascade PER DISTINCT QUERY (constants are baked into the
              plan), the engine one per (template, batch-shape).

A fourth phase measures the PRODUCTION shape (PR 4): `sharded` runs the
same kind of mixed stream through a ServeEngine bound to a forced
8-device mesh over a region-sharded store with `routing="a2a"` — one
`shard_map` dispatch (one all_to_all pair per cascade step) serves the
whole batch — against the per-query `execute_sharded` loop, recording
qps, avg batch, and the static a2a collective payload per query vs the
single-query tuned routed path (the acceptance gates: >= 3x qps at avg
batch >= 8, payload per query within 1.5x). Runs in a subprocess with
`--xla_force_host_platform_device_count` so the caller's device view is
untouched (same pattern as bench_distributed).

Every batched result is verified bit-identical (row set) to
`execute_local` on the same (patterns, cfg); each distinct template
shape is additionally verified against `execute_oracle` on a small
instance (the oracle is O(N) python per binding — too slow at bench
scale). Stream shapes are the selective serving-style queries; the
broad class scans (LUBM Q6/Q14, SP²B Q2) are batch-analytics, not
request traffic.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

import jax

from repro.core import (Caps, ExecConfig, build_store, execute_local,
                        execute_oracle, rows_set)
from repro.core.bgp import order_patterns
from repro.data import lubm_like, sp2b_like
from repro.obs import MetricsRegistry, Tracer
from repro.obs.trace import load_chrome
from repro.serve import EngineBusy, Fault, FaultPlan, ServeEngine

CAPS = Caps(out_cap=128, probe_cap=32, row_cap=16)

# trace/metrics artifacts land here (gitignored); CI uploads the dir
ARTIFACT_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "artifacts")

# comparative phases verify row-identity against execute_local at the SAME
# caps, which requires identical truncation semantics — so the benchmarked
# engines pin max_escalations=0 (the recovery machinery is measured by the
# fault row below and tested in tests/test_robustness.py)
NO_ESC = dict(max_escalations=0)

N_DEPT, N_PROF, N_COURSE = 12, 18, 24     # rdf_gen.lubm_like constants


def _lubm_shapes(d, n_univ, rng):
    """(name, weight, sampler) — samplers draw random constants."""
    p = d.pattern
    u = lambda: rng.randint(n_univ)
    return [
        ("lubm_q1", 3, lambda: (lambda uu, dd: [
            p("?x", "rdf:type", "GraduateStudent"),
            p("?x", "takesCourse",
              f"Course{rng.randint(N_COURSE)}.D{dd}.U{uu}")])(
                  u(), rng.randint(N_DEPT))),
        ("lubm_q3", 3, lambda: (lambda uu, dd: [
            p("?x", "rdf:type", "Publication"),
            p("?x", "publicationAuthor",
              f"Prof{rng.randint(N_PROF)}.D{dd}.U{uu}")])(
                  u(), rng.randint(N_DEPT))),
        ("lubm_q5", 3, lambda: [
            p("?x", "rdf:type", "Student"),
            p("?x", "memberOf", f"Dept{rng.randint(N_DEPT)}.U{u()}")]),
        ("lubm_q13", 3, lambda: [
            p("?p", "worksFor", f"Dept{rng.randint(N_DEPT)}.U{u()}"),
            p("?x", "advisor", "?p")]),
        ("lubm_q7", 2, lambda: (lambda uu, dd: [
            p("?y", "rdf:type", "Course"),
            p(f"Prof{rng.randint(N_PROF)}.D{dd}.U{uu}", "teacherOf", "?y"),
            p("?x", "takesCourse", "?y"),
            p("?x", "rdf:type", "Student")])(u(), rng.randint(N_DEPT))),
        ("lubm_q11", 1, lambda: [
            p("?x", "rdf:type", "ResearchGroup"),
            p("?x", "subOrganizationOf", f"Univ{u()}")]),
        ("lubm_q4star", 2, lambda: (lambda uu, dd: [
            p("?x", "rdf:type", "Professor"),
            p("?x", "worksFor", f"Dept{dd}.U{uu}"),
            p("?x", "name", "?y1"),
            p("?x", "emailAddress", "?y2"),
            p("?x", "telephone", "?y3")])(u(), rng.randint(N_DEPT))),
    ]


def _sp2b_shapes(d, n_articles, rng):
    p = d.pattern
    n_persons = max(n_articles // 3, 8)
    return [
        ("sp2b_title", 3, lambda: [
            p("?a", "rdf:type", "Article"),
            p("?a", "dc:title", f"title{2 * rng.randint(n_articles // 2)}"),
            p("?a", "dcterms:issued", "?yr")]),
        ("sp2b_author", 3, lambda: [
            p("?a", "dc:creator", f"Person{rng.randint(n_persons)}"),
            p("?a", "dc:title", "?t")]),
        ("sp2b_person", 3, lambda: [
            p("?s", "?pr", f"Person{rng.randint(n_persons)}")]),
    ]


def _gen_stream(tenants, n_requests, rng):
    """Mixed request stream: (tenant, shape name, patterns) per request."""
    choices = [(t, name, fn) for t, shapes in tenants.items()
               for name, w, fn in shapes for _ in range(w)]
    return [(lambda t, name, fn: (t, name, fn()))(*choices[rng.randint(
        len(choices))]) for _ in range(n_requests)]


def _block(bnd):
    jax.block_until_ready((bnd.table, bnd.valid, bnd.overflow))
    return bnd


def _run_sequential(stores, reqs, arrivals):
    """FIFO one-at-a-time loop on a virtual clock; returns (lat, makespan)."""
    now, lat = 0.0, []
    for (tenant, _, pats), arr in zip(reqs, arrivals):
        start = max(now, arr)
        t0 = time.perf_counter()
        _block(execute_local(stores[tenant], pats, "mapsin", caps=CAPS))
        now = start + (time.perf_counter() - t0)
        lat.append(now - arr)
    return lat, now


def _run_batched(engines, reqs, arrivals, max_queue_shed=False):
    """Open-loop replay through the shape-bucketing engines; returns
    (lat, makespan, shed). The engine with the deepest queue steps.
    Submits carry the tenant and steps carry the virtual clock, so the
    engines' per-tenant latency histograms (obs metrics) see the same
    clock domain the replay measures latency on."""
    now, i, shed = 0.0, 0, 0
    lat = []
    arr_of = {}
    n = len(reqs)
    while len(lat) + shed < n:
        while i < n and arrivals[i] <= now:
            tenant, _, pats = reqs[i]
            try:
                rid = engines[tenant].submit(pats, arrival=arrivals[i],
                                             tenant=tenant)
                arr_of[(tenant, rid)] = arrivals[i]
            except EngineBusy:         # admission control: load shed (503)
                if not max_queue_shed:
                    raise
                shed += 1
            i += 1
        busiest = max(engines, key=lambda t: engines[t].pending())
        if engines[busiest].pending() == 0:
            if i < n:
                now = max(now, arrivals[i])
                continue
            break
        t0 = time.perf_counter()
        results = engines[busiest].step(now=now)
        now += time.perf_counter() - t0
        for r in results:
            lat.append(now - arr_of[(busiest, r.request_id)])
    return lat, now, shed


# ---------------------------------------------------------------------------
# Sharded batched serving (forced-multi-device; the production shape)
# ---------------------------------------------------------------------------

SHARDED_SHARDS = 8
SHARDED_SHAPES = ("lubm_q1", "lubm_q3", "lubm_q5", "lubm_q13", "lubm_q4star")


def _seq_payload_bytes(store, pats, cfg, caps, num_shards):
    """Static per-shard a2a collective payload of ONE execute_sharded call
    (embedded measured caps; same convention as ServeEngine._payload_bytes
    and bench_distributed: the local diagonal block is excluded)."""
    from repro.core import compile_plan
    from repro.core.bgp import a2a_step_payload_bytes
    plan = compile_plan(store, pats, caps, routing=cfg.routing,
                        num_shards=num_shards)
    total = 0
    for st in plan.steps[1:]:
        if st.kind not in ("mapsin", "multiway"):
            continue
        cap = (st.caps.row_cap if st.kind == "multiway"
               else st.caps.probe_cap)
        total += a2a_step_payload_bytes(st.caps.a2a_bucket_cap, cap,
                                        num_shards)
    return total


def _sharded_mesh_main(emit=print, num_shards=SHARDED_SHARDS, lubm_scale=2,
                       n_requests=160, max_batch=16, n_variants=3,
                       shape_names=SHARDED_SHAPES, seed=0):
    """Body that runs INSIDE the forced-multi-device process: batched
    sharded engine vs the per-query execute_sharded loop, warm on both
    sides, every batched result verified row-identical to execute_local."""
    from jax.sharding import Mesh

    assert jax.device_count() >= num_shards, jax.devices()
    mesh = Mesh(np.array(jax.devices()[:num_shards]), ("data",))
    cfg = ExecConfig(routing="a2a")
    tr, d, _ = lubm_like(lubm_scale)
    store = build_store(tr, num_shards=num_shards)
    rng = np.random.RandomState(seed)
    shapes = [s for s in _lubm_shapes(d, lubm_scale, rng)
              if s[0] in shape_names]
    # fixed per-template variant pools: the sequential loop compiles (and
    # tunes) per DISTINCT query, so unbounded constants would time compiles
    pools = {name: [fn() for _ in range(n_variants)] for name, _, fn in shapes}
    names = [name for name, _, _ in shapes]
    reqs = [pools[names[rng.randint(len(names))]][rng.randint(n_variants)]
            for _ in range(n_requests)]

    engine = ServeEngine(store, d, cfg, caps=CAPS, mesh=mesh,
                         max_batch=max_batch, max_queue=4 * n_requests,
                         compile_cache_size=64, **NO_ESC)

    def run_seq():
        for pats in reqs:
            from repro.core import execute_sharded
            t, v, ovf, _ = execute_sharded(store, pats, mesh, "mapsin", cfg,
                                           caps=CAPS)
            jax.block_until_ready((t, v, ovf))

    # --- warm-up + verification (compiles and tuning paid here) ----------
    results = engine.execute(reqs)
    run_seq()
    verified, ovf_total, local_cache = 0, 0, {}
    for pats, res in zip(reqs, results):
        key = tuple(pats)
        if key not in local_cache:
            bnd = execute_local(store, pats, "mapsin", cfg, caps=CAPS)
            local_cache[key] = (rows_set(bnd.table, bnd.valid, len(bnd.vars)),
                                tuple(bnd.vars))
        want, vars_ = local_cache[key]
        assert res.rows_set(vars_) == want, pats
        verified += 1
        ovf_total += res.overflow

    # --- timed: batched-sharded vs per-query execute_sharded loop --------
    d0, q0 = engine.dispatches, engine.dispatched_queries
    p0 = engine.a2a_payload_bytes
    t0 = time.perf_counter()
    engine.execute(reqs)
    sat_b = time.perf_counter() - t0
    dispatches = engine.dispatches - d0
    avg_batch = (engine.dispatched_queries - q0) / max(dispatches, 1)
    bytes_q_batched = (engine.a2a_payload_bytes - p0) / n_requests
    t0 = time.perf_counter()
    run_seq()
    sat_s = time.perf_counter() - t0
    qps_b, qps_s = n_requests / sat_b, n_requests / sat_s
    bytes_q_seq = float(np.mean([_seq_payload_bytes(store, pats, cfg, CAPS,
                                                    num_shards)
                                 for pats in reqs]))

    emit(f"bench_serving/sharded{num_shards}_lubm{lubm_scale},"
         f"{sat_b / n_requests * 1e6:.0f},"
         f"qps_batched={qps_b:.1f};qps_seq={qps_s:.1f};"
         f"speedup={qps_b / qps_s:.2f};avg_batch={avg_batch:.1f};"
         f"dispatches={dispatches};"
         f"probe_payload_q_batched={bytes_q_batched:.0f};"
         f"probe_payload_q_seq={bytes_q_seq:.0f};"
         f"bytes_ratio={bytes_q_batched / max(bytes_q_seq, 1e-9):.2f};"
         f"verified_local={verified};distinct={len(local_cache)};"
         f"ovf={ovf_total};n={n_requests}")

    # --- 1%-fault row: serving under injected shard faults (PR 6) --------
    # a seeded Bernoulli(1%) FaultPlan over the answer legs, answer-leg
    # checksums + dispatch retries on; p99 must stay within 2x the clean
    # engine's (measured on the same replay protocol), rows stay exact
    def _replay(eng):
        lat, now = [], 0.0
        for pats in reqs:
            eng.submit(pats, arrival=0.0)
        while eng.pending():
            t0 = time.perf_counter()
            results = eng.step(force=True)
            now += time.perf_counter() - t0
            lat.extend(now for _ in results)
        return lat, now

    # deterministic resample until the plan carries a step-0 fault: tiny
    # meshes can roll an empty 1% plan (2 shards x 2 steps x 32 epochs =
    # 128 trials), and a fault-free row would measure nothing
    fseed = seed + 17
    while True:
        fp = FaultPlan.sample(fseed, num_shards, n_steps=2, rate=0.01,
                              horizon=32)
        step0_epochs = [f.epoch for f in fp.faults if f.step == 0]
        if step0_epochs:
            break
        fseed += 1
    feng = ServeEngine(store, d, cfg, caps=CAPS, mesh=mesh,
                       max_batch=max_batch, max_queue=4 * n_requests,
                       compile_cache_size=64, fault_plan=fp,
                       fault_retries=4, **NO_ESC)
    fresults = feng.execute(reqs)                      # warm + verify
    fverified = funrec = 0
    for pats, res in zip(reqs, fresults):
        want, vars_ = local_cache[tuple(pats)]
        if (res.stats or {}).get("fault_unrecovered"):
            funrec += 1                                # quarantined subset
            assert res.rows_set(vars_) <= want, pats   # never WRONG rows
        else:
            assert res.rows_set(vars_) == want, pats
        fverified += 1
    # pin the measurement to one epoch window — anchored at the first
    # step-0 fault so the window provably exercises >= 1 fault — and warm
    # it first: an untimed replay from W compiles every fault selection
    # the window contains, then rewinding to W makes the timed replay
    # traverse the identical (deterministic) epoch sequence: steady-state
    # dispatch + detect/retry cost, not first-encounter XLA compiles
    window_start = min(step0_epochs)
    feng.fault_epoch = window_start
    _replay(feng)
    feng.fault_epoch = window_start
    detected0, redisp0 = feng.corrupt_detected, feng.fault_redispatches
    lat_f, span_f = _replay(feng)
    win_detected = feng.corrupt_detected - detected0
    win_redisp = feng.fault_redispatches - redisp0
    assert win_detected > 0, \
        "1%-fault window exercised no faults — row would be vacuous"
    lat_c, span_c = _replay(engine)
    p99 = lambda xs: float(np.percentile(np.asarray(xs) * 1e3, 99))
    p99_f, p99_c = p99(lat_f), p99(lat_c)
    emit(f"bench_serving/fault1pct_sharded{num_shards}_lubm{lubm_scale},"
         f"{span_f / n_requests * 1e6:.0f},"
         f"qps_fault={n_requests / span_f:.1f};"
         f"qps_clean={n_requests / span_c:.1f};"
         f"p99_ms_fault={p99_f:.2f};p99_ms_clean={p99_c:.2f};"
         f"p99_fault_ratio={p99_f / max(p99_c, 1e-9):.2f};"
         f"detected={win_detected};"
         f"redispatches={win_redisp};"
         f"unrecovered={funrec};verified_local={fverified};n={n_requests}")


def _chaos_mesh_main(emit=print, num_shards=2, lubm_scale=1, seed=0,
                     trace_path=None):
    """Fast-tier chaos canary (runs INSIDE the forced-device process): a
    seeded FaultPlan with one DROPPED and one CORRUPTED a2a answer leg on
    a 2-device mesh; asserts the checksums detect both, the dispatch loop
    recovers by retrying onto clean epochs, and every delivered row set
    is identical to execute_local — zero wrong rows under chaos.  With
    trace_path set, exports the fault-retry span tree (detect -> retry ->
    clean epoch) as a Perfetto-loadable chrome trace."""
    from jax.sharding import Mesh

    assert jax.device_count() >= num_shards, jax.devices()
    mesh = Mesh(np.array(jax.devices()[:num_shards]), ("data",))
    cfg = ExecConfig(routing="a2a")
    tr, d, _ = lubm_like(lubm_scale)
    store = build_store(tr, num_shards=num_shards)
    rng = np.random.RandomState(seed)
    shapes = [s for s in _lubm_shapes(d, lubm_scale, rng)
              if s[0] in ("lubm_q1", "lubm_q5", "lubm_q13")]
    reqs = [fn() for _, _, fn in shapes for _ in range(2)]
    fp = FaultPlan((Fault(0, 0, "drop", epoch=0),
                    Fault(0, 1, "corrupt", epoch=1)))
    tracer = Tracer() if trace_path else None
    eng = ServeEngine(store, d, cfg, caps=CAPS, mesh=mesh, max_batch=4,
                      fault_plan=fp, tracer=tracer,
                      metrics=MetricsRegistry() if trace_path else None,
                      **NO_ESC)
    t0 = time.perf_counter()
    results = eng.execute(reqs)
    span = time.perf_counter() - t0
    if tracer is not None:
        disp = [s for s in tracer.spans if s.name == "dispatch"]
        assert any(s.attrs.get("bad", 0) > 0 for s in disp), "no fault span"
        assert disp[-1].attrs.get("bad") == 0, "last dispatch not clean"
        os.makedirs(os.path.dirname(trace_path) or ".", exist_ok=True)
        tracer.export(trace_path)
        load_chrome(trace_path)        # Perfetto-loadable or die
    verified = 0
    for pats, res in zip(reqs, results):
        bnd = execute_local(store, pats, "mapsin", cfg, caps=CAPS)
        want = rows_set(bnd.table, bnd.valid, len(bnd.vars))
        assert res.rows_set(tuple(bnd.vars)) == want, pats
        assert "fault_unrecovered" not in (res.stats or {}), pats
        verified += 1
    assert eng.corrupt_detected >= 2, eng.corrupt_detected  # drop + corrupt
    assert eng.fault_redispatches >= 2, eng.fault_redispatches
    emit(f"bench_serving/chaos{num_shards}_lubm{lubm_scale},"
         f"{span / len(reqs) * 1e6:.0f},"
         f"detected={eng.corrupt_detected};"
         f"redispatches={eng.fault_redispatches};"
         f"verified_local={verified};n={len(reqs)}")


def _respawn_forced(spec: dict, num_shards: int, emit):
    """Re-run this module in a subprocess with forced host devices (the
    device-count flag must never leak into the caller's jax), re-emitting
    the child's bench rows."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count"
                        f"={num_shards}").strip()
    env["JAX_PLATFORMS"] = "cpu"   # the flag only forces the HOST platform
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..", "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_serving", json.dumps(spec)],
        env=env, capture_output=True, text=True,
        cwd=os.path.join(os.path.dirname(os.path.abspath(__file__)), ".."))
    if out.returncode != 0:
        raise RuntimeError(f"bench_serving sharded subprocess failed:\n"
                           f"{out.stderr[-4000:]}")
    for line in out.stdout.splitlines():
        if line.startswith("bench_serving/"):
            emit(line)


def sharded_main(emit=print, num_shards=SHARDED_SHARDS, lubm_scale=2,
                 n_requests=160, max_batch=16, n_variants=3,
                 shape_names=SHARDED_SHAPES, seed=0):
    """Run the sharded serving suite, respawning in a subprocess with
    forced host devices when the current process doesn't have enough
    (the device-count flag must never leak into the caller's jax)."""
    if jax.device_count() >= num_shards:
        return _sharded_mesh_main(emit, num_shards, lubm_scale, n_requests,
                                  max_batch, n_variants, shape_names, seed)
    _respawn_forced({"num_shards": num_shards, "lubm_scale": lubm_scale,
                     "n_requests": n_requests, "max_batch": max_batch,
                     "n_variants": n_variants,
                     "shape_names": list(shape_names), "seed": seed},
                    num_shards, emit)


def chaos_main(emit=print, num_shards=2, lubm_scale=1, seed=0,
               trace_path=None):
    """Run the chaos canary (CI fast tier: benchmarks/smoke.py), forcing
    a 2-device mesh via subprocess when needed."""
    if jax.device_count() >= num_shards:
        return _chaos_mesh_main(emit, num_shards, lubm_scale, seed,
                                trace_path)
    _respawn_forced({"chaos": True, "num_shards": num_shards,
                     "lubm_scale": lubm_scale, "seed": seed,
                     "trace_path": trace_path},
                    num_shards, emit)


def main(emit=print, lubm_scale=2, sp2b_scale=1000, n_requests=192,
         max_batch=16, seed=0, oracle=True, sharded=True):
    rng = np.random.RandomState(seed)
    lt, ld, _ = lubm_like(lubm_scale)
    st, sd, _ = sp2b_like(sp2b_scale)
    stores = {"lubm": build_store(lt, 1), "sp2b": build_store(st, 1)}
    dicts = {"lubm": ld, "sp2b": sd}
    triples = {"lubm": lt, "sp2b": st}
    shapes = {"lubm": _lubm_shapes(ld, lubm_scale, rng),
              "sp2b": _sp2b_shapes(sd, sp2b_scale, rng)}
    reqs = _gen_stream(shapes, n_requests, rng)
    tag = f"lubm{lubm_scale}_sp2b{sp2b_scale}"

    def fresh_engines():
        # compile cache must hold every (template, pow2-batch) pair or the
        # timed phases would re-pay compiles on eviction
        return {t: ServeEngine(stores[t], dicts[t], caps=CAPS,
                               max_batch=max_batch,
                               max_queue=4 * n_requests,
                               compile_cache_size=64, name=t, **NO_ESC)
                for t in stores}

    # --- cold start (compiles included), then warm both paths -------------
    engines = fresh_engines()
    zero = [0.0] * n_requests
    t0 = time.perf_counter()
    _run_batched(engines, reqs, zero)
    cold_batched = time.perf_counter() - t0
    t0 = time.perf_counter()
    _run_sequential(stores, reqs, zero)
    cold_seq = time.perf_counter() - t0
    # deterministic warm-up: every template at every pow2 batch shape, so
    # neither timed phase below ever waits on a compile (a deployment
    # would do this from a traffic log at startup — ServeEngine.precompile)
    for tenant, _, pats in reqs:
        engines[tenant].precompile(pats)

    # --- saturated throughput (the >= 3x acceptance gate) -----------------
    # wall clock around BOTH loops, so python-side scheduling overhead is
    # charged to the engine that incurs it
    d0 = engines["lubm"].dispatches + engines["sp2b"].dispatches
    t0 = time.perf_counter()
    _run_batched(engines, reqs, zero)
    sat_batched = time.perf_counter() - t0
    dispatches = engines["lubm"].dispatches + engines["sp2b"].dispatches - d0
    t0 = time.perf_counter()
    _run_sequential(stores, reqs, zero)
    sat_seq = time.perf_counter() - t0
    qps_b, qps_s = n_requests / sat_batched, n_requests / sat_seq
    avg_batch = n_requests / max(dispatches, 1)

    # --- observability overhead + coverage gate (ISSUE 8) -----------------
    # re-run the saturated replay on the same warmed engines with a Tracer
    # and a private MetricsRegistry attached, interleaved with untraced
    # re-runs; the qps ratio is the tracing tax (<= 2% at full scale) and
    # the span coverage proves the trace accounts for the engine's wall
    # time. Interleaved min-of-pairs on BOTH sides is the drift-robust
    # estimator on a noisy shared host (machine noise is one-sided — it
    # only ever adds time — so the per-side min approaches each clean
    # time); a genuinely slow tracer cannot hide from it. The tracer is
    # rebuilt per traced run so span accumulation never biases later
    # iterations; the last run's trace is the exported artifact.
    reg = MetricsRegistry()
    prev_reg = {t: engines[t].metrics_registry for t in engines}
    traced_s, off_s = [], []
    tracer = None
    w0 = w1 = 0.0
    for _ in range(8):
        tracer = Tracer()
        for t in engines:
            engines[t].tracer, engines[t].metrics_registry = tracer, reg
        w0 = tracer.now()
        t0 = time.perf_counter()
        _run_batched(engines, reqs, zero)
        traced_s.append(time.perf_counter() - t0)
        w1 = tracer.now()
        for t in engines:
            engines[t].tracer = None
            engines[t].metrics_registry = prev_reg[t]
        t0 = time.perf_counter()
        _run_batched(engines, reqs, zero)
        off_s.append(time.perf_counter() - t0)
        if len(traced_s) >= 3 and min(off_s) / min(traced_s) >= 0.985:
            break
    overhead_ratio = min(off_s) / min(traced_s)   # qps_traced / qps_off
    coverage = tracer.coverage(w0, w1, track="engine")
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    trace_path = os.path.join(ARTIFACT_DIR, "TRACE_serving.json")
    tracer.export(trace_path)
    events = load_chrome(trace_path)   # self-check: Perfetto-loadable
    for t in engines:                  # refresh the qps gauge per engine
        engines[t].metrics_registry = reg
        engines[t].metrics()
        engines[t].metrics_registry = prev_reg[t]
    snap = reg.to_dict()
    with open(os.path.join(ARTIFACT_DIR, "METRICS_serving.json"), "w") as f:
        json.dump(snap, f, indent=1, sort_keys=True)
        f.write("\n")
    hkeys = snap["histograms"]
    assert any(k.startswith("serve_template_latency_seconds") for k in hkeys)
    assert any(k.startswith("serve_tenant_latency_seconds") for k in hkeys)
    p99_ms = {t: 1e3 * snap["histograms"]
              [f'serve_tenant_latency_seconds{{tenant="{t}"}}']["p99"]
              for t in engines}
    full_scale = n_requests >= 64
    if full_scale:        # smoke runs are too short/noisy to gate on
        assert overhead_ratio >= 0.98, (
            f"tracing costs more than 2% qps: ratio={overhead_ratio:.3f} "
            f"(traced {min(traced_s):.3f}s vs off {min(off_s):.3f}s)")
        assert coverage >= 0.95, (
            f"trace covers only {coverage:.1%} of engine wall time")
    emit(f"bench_serving/traced_{tag},"
         f"{min(traced_s) / n_requests * 1e6:.0f},"
         f"trace_overhead_ratio={overhead_ratio:.3f};"
         f"span_coverage={coverage:.3f};"
         f"qps_traced={n_requests / min(traced_s):.0f};"
         f"trace_events={len(events)};"
         f"p99_ms_lubm={p99_ms['lubm']:.2f};p99_ms_sp2b={p99_ms['sp2b']:.2f}")

    # --- verification: every request vs execute_local; shapes vs oracle ---
    engines_v = fresh_engines()
    rid_to_req = {}
    for (tenant, name, pats), _ in zip(reqs, zero):
        rid = engines_v[tenant].submit(pats)
        rid_to_req[(tenant, rid)] = (tenant, name, pats)
    results = {t: {} for t in engines_v}
    for t, eng in engines_v.items():
        for r in eng.drain():
            results[t][r.request_id] = r
    verified = 0
    local_cache = {}
    for (tenant, rid), (t, name, pats) in rid_to_req.items():
        key = (tenant, tuple(pats))
        if key not in local_cache:
            bnd = execute_local(stores[tenant], pats, "mapsin", caps=CAPS)
            local_cache[key] = (rows_set(bnd.table, bnd.valid, len(bnd.vars)),
                                tuple(bnd.vars))
        want, vars_ = local_cache[key]
        got = results[tenant][rid]
        assert got.rows_set(vars_) == want, (tenant, name, pats)
        verified += 1
    verified_oracle = 0
    if oracle:
        vs = {"lubm": lubm_like(1), "sp2b": sp2b_like(300)}
        orng = np.random.RandomState(seed + 1)
        vshapes = {t: _lubm_shapes(vs[t][1], 1, orng) if t == "lubm"
                   else _sp2b_shapes(vs[t][1], 300, orng) for t in vs}
        for t, shp in vshapes.items():
            tr_v, d_v, _ = vs[t]
            store_v = build_store(tr_v, 1)
            eng_v = ServeEngine(store_v, d_v, caps=CAPS,
                                max_batch=max_batch, **NO_ESC)
            for name, _, fn in shp:
                pats = fn()
                res = eng_v.execute([pats])[0]
                # ordered patterns: same result set, tractable oracle
                want, ovars = execute_oracle(
                    tr_v, order_patterns(pats, store=store_v))
                assert res.rows_set(ovars) == want, (t, name)
                verified_oracle += 1

    emit(f"bench_serving/saturated_{tag},{sat_batched / n_requests * 1e6:.0f},"
         f"qps_batched={qps_b:.0f};qps_seq={qps_s:.0f};"
         f"speedup={qps_b / qps_s:.2f};avg_batch={avg_batch:.1f};"
         f"dispatches={dispatches};n={n_requests};"
         f"verified_local={verified};verified_oracle={verified_oracle}")

    # --- open-loop Poisson at 1.5x the sequential engine's capacity -------
    # a load the one-at-a-time loop cannot sustain (its queue grows for
    # the whole run) while the batcher absorbs it with moderate batches;
    # note an open-loop batcher's capacity is batch-size dependent, so
    # rates near qps_batched (which assumes full batches) also saturate
    rate = 1.5 * qps_s
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n_requests)).tolist()
    # untimed replay first: an arrival trickle dispatches small batch
    # shapes (1/2/4/...) the saturated phase never compiled; the timed
    # replay below then measures steady-state latency, not compiles
    _run_batched(engines, reqs, arrivals, max_queue_shed=True)
    lat_b, _, shed = _run_batched(engines, reqs, arrivals,
                                  max_queue_shed=True)
    lat_s, _ = _run_sequential(stores, reqs, arrivals)
    p = lambda xs, q: float(np.percentile(np.asarray(xs) * 1e3, q))
    emit(f"bench_serving/poisson_{tag},{p(lat_b, 99) * 1e3:.0f},"
         f"rate_qps={rate:.0f};p50_ms_batched={p(lat_b, 50):.2f};"
         f"p99_ms_batched={p(lat_b, 99):.2f};p50_ms_seq={p(lat_s, 50):.2f};"
         f"p99_ms_seq={p(lat_s, 99):.2f};shed={shed}")

    emit(f"bench_serving/coldstart_{tag},{cold_batched * 1e6:.0f},"
         f"cold_s_batched={cold_batched:.2f};cold_s_seq={cold_seq:.2f};"
         f"cold_speedup={cold_seq / cold_batched:.2f};"
         f"distinct_queries={len(local_cache)}")

    # --- sharded batched serving (forced 8-device subprocess) -------------
    if sharded:
        sharded_main(emit, seed=seed)
    return qps_b / qps_s


if __name__ == "__main__":
    args = sys.argv[1:]
    if args and args[0].startswith("{"):
        spec = json.loads(args[0])
        if jax.device_count() < spec["num_shards"]:   # spec arg == we ARE
            raise SystemExit(                         # the child; no respawn
                f"forced host devices ineffective: {jax.devices()}")
        if spec.get("chaos"):
            _chaos_mesh_main(print, spec["num_shards"], spec["lubm_scale"],
                             spec["seed"], spec.get("trace_path"))
        else:
            _sharded_mesh_main(print, spec["num_shards"], spec["lubm_scale"],
                               spec["n_requests"], spec["max_batch"],
                               spec["n_variants"],
                               tuple(spec["shape_names"]), spec["seed"])
    else:
        from benchmarks.run import run_suite
        import benchmarks.bench_serving as mod
        run_suite("serving", mod)
