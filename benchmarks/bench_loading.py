"""Paper Table 4: store bulk-load times (both indexes) vs dataset size."""
from __future__ import annotations

import time

from repro.core import build_store
from repro.data import lubm_like, sp2b_like


def main(emit=print, lubm_scales=(1, 2, 4, 8), sp2b_scales=(2000, 4000, 8000)):
    for bench, gen, scales in (("lubm", lubm_like, lubm_scales),
                               ("sp2b", sp2b_like, sp2b_scales)):
        for scale in scales:
            tr, _, _ = gen(scale)
            t0 = time.perf_counter()
            store = build_store(tr, num_shards=8)
            dt = time.perf_counter() - t0
            emit(f"bench_loading/{bench}_x{scale},{dt*1e6:.0f},"
                 f"triples={store.n_triples};triples_per_s={store.n_triples/dt:.0f};"
                 f"bytes={store.storage_bytes()}")


if __name__ == "__main__":
    main()
