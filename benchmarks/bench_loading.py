"""Paper Table 4: store bulk-load times — plus the live-ingest suites
(DESIGN.md §9): ingest-while-serving (sustained triples/s vs query p99,
overlay-merge qps vs the immutable baseline, every sampled row verified
against ``execute_local`` and the ``build_store`` oracle) and the
SIGKILL crash canary (``ingest_crash_main``: a child process ingests
until the parent kills it mid-stream, then recovery must surface every
acknowledged batch and nothing more).
"""
from __future__ import annotations

import os
import shutil
import signal
import subprocess
import sys
import tempfile
import time

import numpy as np

from repro.core import build_store, execute_local, rows_set
from repro.data import lubm_like, sp2b_like

# steady-state serving measurement per wave; small enough that smoke
# (scale 1) stays in seconds, large enough for a stable p99 at scale
QUERIES_PER_WAVE = 24


def _bulk(emit, lubm_scales, sp2b_scales):
    for bench, gen, scales in (("lubm", lubm_like, lubm_scales),
                               ("sp2b", sp2b_like, sp2b_scales)):
        for scale in scales:
            tr, _, _ = gen(scale)
            t0 = time.perf_counter()
            store = build_store(tr, num_shards=8)
            dt = time.perf_counter() - t0
            emit(f"bench_loading/{bench}_x{scale},{dt*1e6:.0f},"
                 f"triples={store.n_triples};triples_per_s={store.n_triples/dt:.0f};"
                 f"bytes={store.storage_bytes()}")


def _rows_canon(bnd, ovars):
    got = rows_set(np.asarray(bnd.table), np.asarray(bnd.valid),
                   len(bnd.vars))
    if tuple(bnd.vars) != tuple(ovars):
        perm = [bnd.vars.index(v) for v in ovars]
        got = set(tuple(r[i] for i in perm) for r in got)
    return got


def ingest_while_serving(emit=print, lubm_scale=2, n_waves=4,
                         preload_frac=0.5, overlay_limit=1 << 16,
                         query_names=("Q1", "Q4"), root=None):
    """Sustained ingest against a serving engine.

    The dataset streams into a ``MutableTripleStore`` in waves; after
    each wave the engine warms once (per-version recompile is paid OFF
    the timed window — the steady-state metric is overlay-merge read
    amplification, not compile time, which is reported separately) and
    then serves a timed query burst. The immutable baseline is a
    ``build_store`` over the identical final content served by an
    identical engine — ``overlay_qps_ratio`` is the mutable/immutable
    qps quotient the acceptance gate reads (>= 0.8x), and every sampled
    row set is verified against ``execute_local`` on BOTH stores and
    must agree exactly."""
    from repro.core import Caps
    from repro.serve import ServeEngine
    from repro.store import MutableTripleStore

    caps = Caps(scan_cap=1 << 15, out_cap=1 << 15, probe_cap=64,
                row_cap=64)
    tr, _d, queries = lubm_like(lubm_scale)
    pats = [list(queries[q]) for q in query_names]
    n = len(tr)
    preload = int(n * preload_frac)
    chunk = max((n - preload) // max(n_waves, 1), 1)

    owns_root = root is None
    root = root or tempfile.mkdtemp(prefix="bench_ingest_")
    store_dir = os.path.join(root, "store")
    try:
        st = MutableTripleStore.create(store_dir, num_shards=1,
                                       overlay_limit=overlay_limit)
        t0 = time.perf_counter()
        st.ingest(tr[:preload])
        preload_s = time.perf_counter() - t0
        st.flush()       # preload becomes the base; waves build the overlay
        eng = ServeEngine(st, caps=caps, max_batch=8)

        ingest_s, served, lat = 0.0, 0, []
        recompile_s = 0.0
        for w in range(n_waves):
            lo = preload + w * chunk
            hi = min(lo + chunk, n) if w < n_waves - 1 else n
            if hi > lo:
                t0 = time.perf_counter()
                st.ingest(tr[lo:hi])
                ingest_s += time.perf_counter() - t0
            t0 = time.perf_counter()
            for p in pats:                      # warm: compile this version
                eng.execute([p])
            recompile_s += time.perf_counter() - t0
            for i in range(QUERIES_PER_WAVE):
                p = pats[i % len(pats)]
                t0 = time.perf_counter()
                eng.execute([p])
                lat.append(time.perf_counter() - t0)
                served += 1
        assert st.n_triples > 0 and st.overlay_depth > 0, \
            "timed waves must serve from a populated overlay"
        mut_qps = served / sum(lat)
        p99_ms = float(np.percentile(np.array(lat) * 1e3, 99))
        ingested = n - preload

        # immutable baseline: same content, same engine config
        base = build_store(tr, num_shards=1)
        beng = ServeEngine(base, caps=caps, max_batch=8)
        for p in pats:
            beng.execute([p])                   # warm
        blat = []
        for i in range(QUERIES_PER_WAVE * n_waves):
            p = pats[i % len(pats)]
            t0 = time.perf_counter()
            beng.execute([p])
            blat.append(time.perf_counter() - t0)
        imm_qps = len(blat) / sum(blat)
        ratio = mut_qps / imm_qps

        # verify: engine rows == execute_local on the mutable store ==
        # execute_local on the immutable oracle, for every bench query
        verified = 1
        for p in pats:
            res = eng.execute([p])[0]
            lm = _rows_canon(execute_local(st, p, caps=caps), res.vars)
            li = _rows_canon(execute_local(base, p, caps=caps), res.vars)
            if not (res.rows_set() == lm == li):
                verified = 0
        st.close()
        emit(f"bench_loading/ingest_serve_lubm_x{lubm_scale},"
             f"{p99_ms*1e3:.0f},"
             f"triples_per_s={ingested/max(ingest_s, 1e-9):.0f};"
             f"preload_triples_per_s={preload/max(preload_s, 1e-9):.0f};"
             f"p99_ms={p99_ms:.2f};qps={mut_qps:.0f};"
             f"qps_immutable={imm_qps:.0f};"
             f"overlay_qps_ratio={ratio:.3f};verified={verified};"
             f"recompile_s={recompile_s:.2f};flushes={st.flush_count};"
             f"overlay_depth={st.overlay_depth};"
             f"n_triples={st.n_triples}")
        if not verified:
            raise AssertionError(
                "ingest-while-serving row verification failed")
    finally:
        if owns_root:
            shutil.rmtree(root, ignore_errors=True)


def _crash_child(store_dir: str, seed: int) -> None:
    """Child process: ingest deterministic batches forever, printing
    ``acked <i>`` after each fsync — until the parent SIGKILLs us."""
    from repro.store import MutableTripleStore
    st = MutableTripleStore.create(store_dir, num_shards=2,
                                   overlay_limit=256)
    rng = np.random.RandomState(seed)
    i = 0
    while True:
        b = np.stack([rng.randint(0, 64, 32), rng.randint(0, 8, 32),
                      rng.randint(0, 64, 32)], 1).astype(np.int32)
        st.ingest(b)
        print(f"acked {i}", flush=True)
        i += 1


def ingest_crash_main(emit=print, seed=0, kill_after_acks=6,
                      root=None) -> None:
    """SIGKILL crash canary: a child ingests deterministic batches and
    reports each fsynced ack on stdout; the parent kills it dead (no
    atexit, no flush — exactly a crash) after `kill_after_acks` acks,
    recovers the directory, and verifies (a) every batch acked before
    the kill is fully present, (b) the recovered content is EXACTLY a
    prefix of the deterministic batch stream — a torn tail may round
    down to the last complete record but can never invent triples."""
    from repro.core.rdf import pack3
    from repro.store import MutableTripleStore

    owns_root = root is None
    root = root or tempfile.mkdtemp(prefix="bench_crash_")
    store_dir = os.path.join(root, "store")
    try:
        child = subprocess.Popen(
            [sys.executable, "-m", "benchmarks.bench_loading",
             "--crash-child", store_dir, str(seed)],
            stdout=subprocess.PIPE, text=True,
            cwd=os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             ".."),
            env={**os.environ,
                 "PYTHONPATH": "src" + os.pathsep
                 + os.environ.get("PYTHONPATH", "")})
        acked = 0
        for line in child.stdout:
            if line.startswith("acked "):
                acked = int(line.split()[1]) + 1
            if acked >= kill_after_acks:
                break
        child.send_signal(signal.SIGKILL)       # mid-stream, no cleanup
        child.wait()

        t0 = time.perf_counter()
        st = MutableTripleStore.open(store_dir)
        recovery_s = time.perf_counter() - t0

        # reconstruct the deterministic batch stream and find the prefix
        # the recovered store equals (>= the acks the parent observed)
        rng = np.random.RandomState(seed)
        got = np.sort(np.concatenate([st._bk_spo, st._ov_spo]))
        prefix, keys = None, np.zeros(0, np.int64)
        for i in range(acked + 64):
            if np.array_equal(got, keys):
                prefix = i
                break
            b = np.stack([rng.randint(0, 64, 32), rng.randint(0, 8, 32),
                          rng.randint(0, 64, 32)], 1)
            keys = np.union1d(keys, pack3(b[:, 0], b[:, 1], b[:, 2]))
        verified = int(prefix is not None and prefix >= acked)
        st.close()
        emit(f"bench_loading/ingest_crash,{recovery_s*1e6:.0f},"
             f"acked_batches={acked};recovered_batches={prefix if prefix is not None else -1};"
             f"verified={verified};recovery_ms={recovery_s*1e3:.1f}")
        if not verified:
            raise AssertionError(
                f"crash recovery verification failed: child acked {acked} "
                f"batches, recovered prefix is {prefix}")
    finally:
        if owns_root:
            shutil.rmtree(root, ignore_errors=True)


def main(emit=print, lubm_scales=(1, 2, 4, 8),
         sp2b_scales=(2000, 4000, 8000), ingest_lubm_scale=2,
         ingest_waves=4, crash_canary=True):
    _bulk(emit, lubm_scales, sp2b_scales)
    if ingest_lubm_scale:
        ingest_while_serving(emit, lubm_scale=ingest_lubm_scale,
                             n_waves=ingest_waves)
    if crash_canary:
        ingest_crash_main(emit)


if __name__ == "__main__":
    if len(sys.argv) >= 4 and sys.argv[1] == "--crash-child":
        _crash_child(sys.argv[2], int(sys.argv[3]))
    else:
        main()
