"""Paper Figure 6 + Section 4.3: multiway star join vs cascaded 2-way joins.

The paper reports 1.4x-3.3x from the single-row-GET optimization; here we
report wall time AND the round/traffic savings (n-1 collective rounds)."""
from __future__ import annotations

import time

from repro.core import Caps, build_store, compile_plan, execute_local, query_traffic
from repro.data import lubm_like, sp2b_like

CAPS = Caps(scan_cap=1 << 16, out_cap=1 << 16, probe_cap=16, row_cap=64)


def _time(fn, repeats=3):
    import jax
    fn()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn().table)
        ts.append(time.perf_counter() - t0)
    return min(ts)


def main(emit=print, lubm_scale=2, sp2b_scale=4000, caps=CAPS):
    cases = []
    tr, _, qs = lubm_like(lubm_scale)
    cases.append(("lubm_Q4", tr, qs["Q4"]))
    tr2, _, qs2 = sp2b_like(sp2b_scale)
    cases.append(("sp2b_Q1", tr2, qs2["Q1"]))
    cases.append(("sp2b_Q2", tr2, qs2["Q2"]))
    for name, tr, pats in cases:
        store = build_store(tr, 1)
        plan_mw = compile_plan(store, pats, caps, multiway=True)
        plan_2w = compile_plan(store, pats, caps, multiway=False)
        t_mw = _time(lambda: execute_local(store, plan_mw))
        t_2w = _time(lambda: execute_local(store, plan_2w))
        b_mw = query_traffic(plan_mw, "mapsin_routed", caps, 10)
        b_2w = query_traffic(plan_2w, "mapsin_routed", caps, 10)
        emit(f"bench_multiway/{name},{t_mw*1e6:.0f},"
             f"multiway_us={t_mw*1e6:.0f};cascade_us={t_2w*1e6:.0f};"
             f"speedup={t_2w/max(t_mw,1e-9):.2f};"
             f"bytes_multiway={b_mw};bytes_cascade={b_2w}")


if __name__ == "__main__":
    main()
