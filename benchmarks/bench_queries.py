"""Paper Table 5 / Figures 4-7: query execution, MAPSIN vs reduce-side.

Reports, per (benchmark, query, scale): wall time of both engines on CPU and
the modeled interconnect bytes for a 10-shard cluster (the paper's 10-node
setup) — bytes are the scale-valid metric in this container; wall time is
the laptop-scale sanity check (both engines run the same JAX substrate).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.core import Caps, build_store, execute_local
from repro.core.bgp import query_traffic_actual
from repro.data import lubm_like, sp2b_like

CAPS = Caps(scan_cap=1 << 16, out_cap=1 << 13, probe_cap=128, row_cap=64)

LUBM_QUERIES = ["Q1", "Q3", "Q4", "Q5", "Q6", "Q7", "Q8", "Q11", "Q13", "Q14"]
SP2B_QUERIES = ["Q1", "Q2", "Q3a", "Q10"]


def _time(fn, repeats=3):
    jax.block_until_ready(jax.tree.leaves(fn()))  # compile
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        # block on the FULL Bindings pytree — timing only .table would let
        # valid/overflow work escape the measured region
        jax.block_until_ready((out.table, out.valid, out.overflow))
        ts.append(time.perf_counter() - t0)
    return min(ts)


def run(scales=(1, 2, 4), emit=print, lubm_queries=LUBM_QUERIES,
        sp2b_queries=SP2B_QUERIES, repeats=3):
    rows = []
    for bench, gen, queries, qnames in (
            ("lubm", lubm_like, None, lubm_queries),
            ("sp2b", sp2b_like, None, sp2b_queries)):
        for scale in scales:
            arg = scale if bench == "lubm" else scale * 2000
            tr, d, qs = gen(arg)
            store = build_store(tr, 1)
            for qname in qnames:
                pats = qs[qname]
                res = {}
                for mode in ("mapsin", "reduce"):
                    t = _time(lambda m=mode: execute_local(store, pats, m,
                                                           caps=CAPS),
                              repeats=repeats)
                    res[mode] = t
                stats: list = []
                execute_local(store, pats, "mapsin", caps=CAPS, stats=stats)
                mr = query_traffic_actual(stats, "mapsin_routed", 10, store.n_triples)
                rd = query_traffic_actual(stats, "reduce", 10, store.n_triples)
                speed = res["reduce"] / max(res["mapsin"], 1e-9)
                movex = rd["total"] / max(mr["total"], 1)
                emit(f"bench_queries/{bench}_{qname}_x{scale},"
                     f"{res['mapsin']*1e6:.0f},"
                     f"mapsin_us={res['mapsin']*1e6:.0f};reduce_us={res['reduce']*1e6:.0f};"
                     f"speedup={speed:.2f};data_moved_ratio={movex:.1f};"
                     f"net_mapsin={mr['network']};scan_mapsin={mr['scanned']};"
                     f"net_reduce={rd['network']};scan_reduce={rd['scanned']};"
                     f"triples={len(tr)}")
                rows.append((bench, qname, scale, res, speed, movex))
    return rows


def main(emit=print):
    run(emit=emit)


if __name__ == "__main__":
    main()
