"""Bench-suite crash canary: every suite at minimal scale, < 60 s total.

``python -m benchmarks.smoke`` (or ``python -m benchmarks.run --smoke``)
exercises each benchmark module end-to-end on tiny inputs and exits
nonzero if any suite raises — so regressions in the bench code itself
(API drift, broken imports, shape bugs) are caught by one plain command
without paying for a full perf run. No BENCH_*.json artifacts are
written at smoke scale (they would clobber the real perf trajectory).

Exception: bench_distributed is NOT smoked here — it spawns an 8-device
subprocess and pays minutes of shard_map compiles even at minimal scale;
its engine path is covered by tests/test_multidevice.py instead. The
sharded SERVING path IS smoked (serving_sharded): a forced 2-device
subprocess at tiny scale compiles only a handful of small template
cascades, cheap enough to keep the one crash canary covering the full
production shape (shard_map + routing="a2a" + batched engine).
"""
from __future__ import annotations

import os
import sys
import time
import traceback


def main() -> int:
    from benchmarks import (bench_kernels, bench_loading, bench_multiway,
                            bench_queries, bench_selectivity, bench_serving)
    import dataclasses
    small_mw = dataclasses.replace(bench_multiway.CAPS, out_cap=1 << 12,
                                   scan_cap=1 << 12, row_cap=16)
    suites = [
        ("loading", lambda emit: bench_loading.main(
            emit=emit, lubm_scales=(1,), sp2b_scales=(500,),
            ingest_lubm_scale=1, ingest_waves=2, crash_canary=False)),
        # durability canary (PR 8): a child process ingests WAL-synced
        # batches until the parent SIGKILLs it mid-stream, then recovery
        # must surface every acknowledged batch and nothing more
        ("ingest_crash", lambda emit: bench_loading.ingest_crash_main(
            emit=emit, kill_after_acks=4)),
        ("queries", lambda emit: bench_queries.run(
            scales=(1,), emit=emit, lubm_queries=("Q1", "Q4"),
            sp2b_queries=("Q10",), repeats=1)),
        ("multiway", lambda emit: bench_multiway.main(
            emit=emit, lubm_scale=1, sp2b_scale=500, caps=small_mw)),
        # selectivity also smokes the planner's cost-vs-heuristic ordering
        # gate (order_* rows assert row-identity + probe_ratio >= 1)
        ("selectivity", lambda emit: bench_selectivity.main(
            emit=emit, n=20_000, lubm_scale=1, repeats=1)),
        ("kernels", lambda emit: bench_kernels.main(
            emit=emit, sizes=((1 << 12, 1 << 8),))),
        ("serving", lambda emit: bench_serving.main(
            emit=emit, lubm_scale=1, sp2b_scale=300, n_requests=12,
            max_batch=8, oracle=False, sharded=False)),
        ("serving_sharded", lambda emit: bench_serving.sharded_main(
            emit=emit, num_shards=2, lubm_scale=1, n_requests=6,
            max_batch=4, n_variants=2, shape_names=("lubm_q1", "lubm_q5"))),
        # chaos canary (PR 6): seeded FaultPlan with one dropped + one
        # corrupted a2a answer leg on a forced 2-device mesh — asserts
        # checksum detection, dispatch-retry recovery, and row-identity
        # vs execute_local (zero wrong rows under chaos); PR 7 adds the
        # exported fault-retry trace (detect -> retry -> clean epoch),
        # uploaded by CI as a workflow artifact
        ("serving_chaos", lambda emit: bench_serving.chaos_main(
            emit=emit, num_shards=2, lubm_scale=1,
            trace_path=os.path.join(bench_serving.ARTIFACT_DIR,
                                    "TRACE_chaos.json"))),
    ]
    failures = []
    for name, fn in suites:
        t0 = time.perf_counter()
        try:
            fn(print)
            print(f"smoke/{name},OK,{time.perf_counter() - t0:.1f}s")
        except Exception:
            traceback.print_exc()
            print(f"smoke/{name},FAIL,{time.perf_counter() - t0:.1f}s")
            failures.append(name)
    if failures:
        print(f"smoke: FAILED suites: {', '.join(failures)}")
        return 1
    print("smoke: all suites passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
