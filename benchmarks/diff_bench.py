"""Old-vs-new BENCH_*.json derived-metric diff (markdown, for CI).

``python -m benchmarks.diff_bench [GIT_REF]`` compares the committed
benchmark trajectory files against the same files at GIT_REF (default
``HEAD^``) and prints a markdown table of the changed derived metrics —
CI appends it to the GitHub Actions job summary so a perf regression is
visible on the push that caused it, without downloading artifacts.

Only rows whose value moved by >= CHANGE_THRESHOLD (or appeared /
disappeared) are printed; headline metrics (speedup/qps/ratio families)
are always listed for new rows. Exits 0 even when the ref has no BENCH
files (first push, shallow clone) — the diff is advisory, never a gate.
"""
from __future__ import annotations

import glob
import json
import os
import subprocess
import sys

CHANGE_THRESHOLD = 0.05          # 5% relative move is worth a line
HEADLINE = ("speedup", "qps_batched", "qps_seq", "time_ratio",
            "cold_speedup", "bytes_ratio", "avg_batch", "p99_ms_batched",
            "probe_ratio", "order_changed", "p99_fault_ratio",
            "trace_overhead_ratio", "span_coverage",
            "overlay_qps_ratio", "triples_per_s", "recovery_ms")
REPO = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")


def _load_ref(ref: str, relpath: str):
    out = subprocess.run(["git", "show", f"{ref}:{relpath}"],
                         capture_output=True, text=True, cwd=REPO)
    if out.returncode != 0:
        return None
    try:
        return json.loads(out.stdout)
    except json.JSONDecodeError:
        return None


def _metrics(row) -> dict:
    if not isinstance(row, dict):        # hand-edited / truncated file
        return {}
    out = {"us": row.get("us")}
    for k, v in row.get("derived", {}).items():
        if isinstance(v, (int, float)):
            out[k] = v
    return out


def _meta_line(new: dict) -> str | None:
    meta = new.get("meta")
    if not isinstance(meta, dict):
        return None
    return (f"stamped {meta.get('git_sha') or '?'} @ "
            f"{meta.get('platform') or '?'}"
            f"x{meta.get('device_count') or '?'}, "
            f"{meta.get('timestamp') or '?'}")


def diff_lines(ref: str = "HEAD^"):
    lines = [f"### Benchmark trajectory vs `{ref}`", "",
             "| row | metric | old | new | change |",
             "|---|---|---:|---:|---:|"]
    n_changes = 0
    stamp = None
    for path in sorted(glob.glob(os.path.join(REPO, "benchmarks",
                                              "BENCH_*.json"))):
        rel = os.path.relpath(path, REPO)
        try:
            with open(path) as f:
                new = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            # advisory diff: a missing/garbled suite file gets a note,
            # never a traceback
            lines.append(f"| {rel} | — | — | — | unreadable "
                         f"({type(e).__name__}) |")
            n_changes += 1
            continue
        if not isinstance(new, dict):
            lines.append(f"| {rel} | — | — | — | not a bench doc |")
            n_changes += 1
            continue
        stamp = stamp or _meta_line(new)
        old = _load_ref(ref, rel)
        old_rows = old.get("rows", {}) if isinstance(old, dict) else {}
        if not isinstance(old_rows, dict):
            old_rows = {}
        rows = new.get("rows", {})
        if not isinstance(rows, dict):
            rows = {}
        for name, row in sorted(rows.items()):
            new_m = _metrics(row)
            old_m = _metrics(old_rows[name]) if name in old_rows else None
            for metric, nv in sorted(new_m.items()):
                if nv is None:
                    continue
                if old_m is None:
                    if metric in HEADLINE:
                        lines.append(f"| {name} | {metric} | — | {nv:g} "
                                     f"| new |")
                        n_changes += 1
                    continue
                ov = old_m.get(metric)
                if ov is None or ov == nv:
                    continue
                delta = (nv - ov) / abs(ov) if ov else float("inf")
                if abs(delta) < CHANGE_THRESHOLD and metric not in HEADLINE:
                    continue
                lines.append(f"| {name} | {metric} | {ov:g} | {nv:g} "
                             f"| {delta:+.1%} |")
                n_changes += 1
    if n_changes == 0:
        lines = [f"Benchmark trajectory vs `{ref}`: no metric moved by "
                 f">= {CHANGE_THRESHOLD:.0%}."]
    if stamp:
        lines += ["", f"_new files {stamp}_"]
    return lines


def main() -> int:
    ref = sys.argv[1] if len(sys.argv) > 1 else "HEAD^"
    probe = subprocess.run(["git", "rev-parse", "--verify", ref],
                           capture_output=True, text=True, cwd=REPO)
    if probe.returncode != 0:
        print(f"Benchmark trajectory: ref `{ref}` not available "
              f"(first commit or shallow clone) — nothing to diff.")
        return 0
    print("\n".join(diff_lines(ref)))
    return 0


if __name__ == "__main__":
    sys.exit(main())
