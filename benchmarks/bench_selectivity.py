"""Paper §5 claim + the planner's ordering gate.

Two row families in ``BENCH_selectivity.json``:

* ``bench_selectivity/<high|low>`` — the original §5 sweep: the MAPSIN
  win grows with join selectivity (wall time + modeled traffic ratio on
  a synthetic graph).
* ``bench_selectivity/order_*`` — the ISSUE 5 acceptance gate: for each
  benchmarked query, the COST-BASED join order (``compile_plan``,
  exhaustive left-deep over exact cardinality + group-fanout stats) vs
  the variable-counting HEURISTIC (``ordering="heuristic"``): per-query
  wall time and measured probe bytes (``query_traffic_actual`` on an
  instrumented run of each plan, 10-shard routed model). The bench
  ASSERTS 100% row-identical results and that cost-based probe bytes
  never exceed the heuristic's (``probe_ratio >= 1``); the ``trap``
  query (an unselective 1-variable pattern vs a small 2-variable
  relation — exactly the shape variable counting gets wrong) is where
  cost-based ordering must be strictly better.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (Caps, Pattern, build_store, compile_plan,
                        execute_local, rows_set)
from repro.core.bgp import query_traffic_actual

ROUTE_SHARDS = 10


def _timed(store, plan, repeats=3):
    import jax
    fn = lambda: execute_local(store, plan)
    jax.block_until_ready(fn().table)            # compile
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready((out.table, out.valid, out.overflow))
        ts.append(time.perf_counter() - t0)
    return min(ts), out


def _probe_bytes(store, plan):
    stats: list = []
    execute_local(store, plan, stats=stats)
    t = query_traffic_actual(stats, "mapsin_routed", ROUTE_SHARDS,
                             store.n_triples)
    return t["network"] + t["scanned"]


def _order_rows(emit, lubm_scale=1, repeats=3):
    """Cost-based vs heuristic ordering on every multi-pattern LUBM query
    plus the heuristic-trap query."""
    from repro.data import lubm_like
    tr, d, qs = lubm_like(lubm_scale)
    store = build_store(tr, 1)
    caps = Caps(scan_cap=1 << 16, out_cap=1 << 16, probe_cap=128, row_cap=64)
    q = d.pattern
    cases = {f"lubm_{name}": pats for name, pats in qs.items()
             if len(pats) > 1}
    # the trap: "?x rdf:type Student" has ONE variable (ranked first by
    # variable counting) but a 1440-row relation; "?x advisor ?p" has two
    # variables but only 360 rows — the cost-based search must flip them
    cases["trap"] = [q("?x", "rdf:type", "Student"),
                     q("?x", "advisor", "?p")]
    strict_wins = 0
    for name, pats in sorted(cases.items()):
        plan_c = compile_plan(store, pats, caps, ordering="cost")
        plan_h = compile_plan(store, pats, caps, ordering="heuristic")
        t_c, bnd_c = _timed(store, plan_c, repeats)
        t_h, bnd_h = _timed(store, plan_h, repeats)
        rows_c = rows_set(bnd_c.table, bnd_c.valid, len(bnd_c.vars))
        rows_h = rows_set(bnd_h.table, bnd_h.valid, len(bnd_h.vars))
        if tuple(bnd_c.vars) != tuple(bnd_h.vars):
            perm = [bnd_c.vars.index(v) for v in bnd_h.vars]
            rows_c = set(tuple(r[i] for i in perm) for r in rows_c)
        assert rows_c == rows_h, \
            f"{name}: cost order changed the result ({len(rows_c)} vs " \
            f"{len(rows_h)} rows)"
        b_c = _probe_bytes(store, plan_c)
        b_h = _probe_bytes(store, plan_h)
        assert b_c <= b_h, \
            f"{name}: cost-based order moves MORE bytes ({b_c} > {b_h})"
        if b_c < b_h:
            strict_wins += 1
        changed = int(plan_c.steps != plan_h.steps)
        emit(f"bench_selectivity/order_{name},{t_c * 1e6:.0f},"
             f"cost_us={t_c * 1e6:.0f};heur_us={t_h * 1e6:.0f};"
             f"time_ratio={t_h / max(t_c, 1e-9):.2f};"
             f"probe_bytes_cost={b_c};probe_bytes_heur={b_h};"
             f"probe_ratio={b_h / max(b_c, 1):.2f};"
             f"order_changed={changed};identical=1;rows={len(rows_c)}")
    assert strict_wins >= 1, "cost-based ordering never strictly won"


def main(emit=print, n=200_000, lubm_scale=1, repeats=3):
    rng = np.random.RandomState(0)
    tr = np.stack([rng.randint(0, 20000, n), rng.randint(100, 110, n),
                   rng.randint(0, 20000, n)], 1).astype(np.int32)
    store = build_store(tr, 1)
    caps = Caps(scan_cap=1 << 16, out_cap=1 << 16, probe_cap=16)
    import jax
    for sel_obj, label in ((3, "high"), (None, "low")):
        if sel_obj is None:
            pats = [Pattern("?x", 101, "?y"), Pattern("?y", 102, "?z")]
        else:
            pats = [Pattern("?x", 101, sel_obj), Pattern("?x", 102, "?z")]
        times = {}
        for mode in ("mapsin", "reduce"):
            fn = lambda m=mode: execute_local(store, pats, m, caps=caps)
            fn()
            t0 = time.perf_counter()
            jax.block_until_ready(fn().table)
            times[mode] = time.perf_counter() - t0
        stats = []
        execute_local(store, pats, "mapsin", caps=caps, stats=stats)
        br = query_traffic_actual(stats, "reduce", ROUTE_SHARDS,
                                  store.n_triples)["total"]
        bm = query_traffic_actual(stats, "mapsin_routed", ROUTE_SHARDS,
                                  store.n_triples)["total"]
        emit(f"bench_selectivity/{label},{times['mapsin']*1e6:.0f},"
             f"mapsin_us={times['mapsin']*1e6:.0f};reduce_us={times['reduce']*1e6:.0f};"
             f"speedup={times['reduce']/max(times['mapsin'],1e-9):.2f};"
             f"traffic_ratio={br/max(bm,1):.1f}")
    _order_rows(emit, lubm_scale=lubm_scale, repeats=repeats)


if __name__ == "__main__":
    main()
