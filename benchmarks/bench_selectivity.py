"""Paper Section 5 claim: the MAPSIN win grows with join selectivity.

Sweeps a constant-object filter's selectivity on a synthetic graph and
reports MAPSIN vs reduce-side wall time + modeled traffic ratio."""
from __future__ import annotations

import time

import numpy as np

from repro.core import ExecConfig, Pattern, build_store, execute_local
from repro.core.bgp import query_traffic_actual


def main(emit=print, n=200_000):
    rng = np.random.RandomState(0)
    tr = np.stack([rng.randint(0, 20000, n), rng.randint(100, 110, n),
                   rng.randint(0, 20000, n)], 1).astype(np.int32)
    store = build_store(tr, 1)
    cfg = ExecConfig(scan_cap=1 << 16, out_cap=1 << 16, probe_cap=16)
    import jax
    for sel_obj, label in ((3, "high"), (None, "low")):
        if sel_obj is None:
            pats = [Pattern("?x", 101, "?y"), Pattern("?y", 102, "?z")]
        else:
            pats = [Pattern("?x", 101, sel_obj), Pattern("?x", 102, "?z")]
        times = {}
        for mode in ("mapsin", "reduce"):
            fn = lambda m=mode: execute_local(store, pats, m, cfg)
            fn()
            t0 = time.perf_counter()
            jax.block_until_ready(fn().table)
            times[mode] = time.perf_counter() - t0
        stats = []
        execute_local(store, pats, "mapsin", cfg, stats=stats)
        br = query_traffic_actual(stats, "reduce", 10, store.n_triples)["total"]
        bm = query_traffic_actual(stats, "mapsin_routed", 10, store.n_triples)["total"]
        emit(f"bench_selectivity/{label},{times['mapsin']*1e6:.0f},"
             f"mapsin_us={times['mapsin']*1e6:.0f};reduce_us={times['reduce']*1e6:.0f};"
             f"speedup={times['reduce']/max(times['mapsin'],1e-9):.2f};"
             f"traffic_ratio={br/max(bm,1):.1f}")


if __name__ == "__main__":
    main()
