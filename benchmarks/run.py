"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines and, per suite, writes a
machine-readable ``BENCH_<suite>.json`` next to this file (name ->
microseconds + parsed derived metrics) so successive PRs can diff the
perf trajectory with a plain ``git diff`` / ``jq``:
  bench_loading      — paper Table 4  (bulk load times)
  bench_queries      — paper Table 5 / Figs 4,5,7 (MAPSIN vs reduce-side)
  bench_multiway     — paper Fig 6 / §4.3 (star-join single-GET optimization)
  bench_selectivity  — paper §5 analysis (win grows with selectivity) +
                       the planner's cost-based vs heuristic ordering gate
  bench_kernels      — kernel hot-spot microbenches
  bench_serving      — serving layer (DESIGN.md §5): batched engine
                       throughput/latency vs the sequential loop

``python -m benchmarks.run --smoke`` (or ``python -m benchmarks.smoke``)
runs every suite at minimal scale as a crash canary; see smoke.py.

Roofline terms come from the dry-run artifacts: see
``python -m repro.launch.roofline`` (reads experiments/dryrun/*.json).
"""
from __future__ import annotations

import json
import os
import sys


def _parse_derived(derived: str) -> dict:
    out = {}
    for part in derived.split(";"):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        try:
            out[k] = float(v) if "." in v or "e" in v.lower() else int(v)
        except ValueError:
            out[k] = v
    return out


def write_bench_json(suite: str, rows: dict, out_dir: str | None = None) -> str:
    path = os.path.join(out_dir or os.path.dirname(os.path.abspath(__file__)),
                        f"BENCH_{suite}.json")
    with open(path, "w") as f:
        json.dump({"suite": suite, "rows": rows}, f, indent=1, sort_keys=True)
        f.write("\n")
    return path


def run_suite(name: str, mod, emit=print) -> str:
    """Run one suite, tee its CSV lines to `emit`, write BENCH_<name>.json."""
    rows: dict = {}

    def tee(line: str):
        emit(line)
        parts = str(line).split(",", 2)
        if len(parts) >= 2:
            try:
                us = float(parts[1])
            except ValueError:
                return
            rows[parts[0]] = {
                "us": us,
                "derived": _parse_derived(parts[2]) if len(parts) > 2 else {},
            }

    mod.main(emit=tee)
    return write_bench_json(name, rows)


def main() -> None:
    args = [a for a in sys.argv[1:]]
    if "--smoke" in args:
        from benchmarks import smoke
        raise SystemExit(smoke.main())
    from benchmarks import (bench_distributed, bench_kernels, bench_loading,
                            bench_multiway, bench_queries, bench_selectivity,
                            bench_serving)
    mods = {
        "loading": bench_loading,
        "queries": bench_queries,
        "multiway": bench_multiway,
        "selectivity": bench_selectivity,
        "kernels": bench_kernels,
        "serving": bench_serving,
        "distributed": bench_distributed,
    }
    only = args[0] if args else None
    print("name,us_per_call,derived")
    for name, mod in mods.items():
        if only and name != only:
            continue
        run_suite(name, mod)


if __name__ == "__main__":
    main()
