"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines:
  bench_loading      — paper Table 4  (bulk load times)
  bench_queries      — paper Table 5 / Figs 4,5,7 (MAPSIN vs reduce-side)
  bench_multiway     — paper Fig 6 / §4.3 (star-join single-GET optimization)
  bench_selectivity  — paper §5 analysis (win grows with selectivity)
  bench_kernels      — kernel hot-spot microbenches

Roofline terms come from the dry-run artifacts: see
``python -m repro.launch.roofline`` (reads experiments/dryrun/*.json).
"""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (bench_kernels, bench_loading, bench_multiway,
                            bench_queries, bench_selectivity)
    mods = {
        "loading": bench_loading,
        "queries": bench_queries,
        "multiway": bench_multiway,
        "selectivity": bench_selectivity,
        "kernels": bench_kernels,
    }
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for name, mod in mods.items():
        if only and name != only:
            continue
        mod.main(emit=print)


if __name__ == "__main__":
    main()
