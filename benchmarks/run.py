"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines and, per suite, writes a
machine-readable ``BENCH_<suite>.json`` next to this file (name ->
microseconds + parsed derived metrics) so successive PRs can diff the
perf trajectory with a plain ``git diff`` / ``jq``:
  bench_loading      — paper Table 4  (bulk load times)
  bench_queries      — paper Table 5 / Figs 4,5,7 (MAPSIN vs reduce-side)
  bench_multiway     — paper Fig 6 / §4.3 (star-join single-GET optimization)
  bench_selectivity  — paper §5 analysis (win grows with selectivity) +
                       the planner's cost-based vs heuristic ordering gate
  bench_kernels      — kernel hot-spot microbenches
  bench_serving      — serving layer (DESIGN.md §5): batched engine
                       throughput/latency vs the sequential loop

``python -m benchmarks.run --smoke`` (or ``python -m benchmarks.smoke``)
runs every suite at minimal scale as a crash canary; see smoke.py.

Roofline terms come from the dry-run artifacts: see
``python -m repro.launch.roofline`` (reads experiments/dryrun/*.json).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time


def run_meta() -> dict:
    """Provenance stamped into every BENCH_*.json: which commit, which
    devices, when.  Each probe degrades to None rather than failing the
    bench (detached checkouts, no-git tarballs, driverless CI)."""
    meta = {"timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
            "git_sha": None, "platform": None, "device_count": None}
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)))
        if out.returncode == 0:
            meta["git_sha"] = out.stdout.strip()
    except OSError:
        pass
    try:
        import jax
        meta["platform"] = jax.default_backend()
        meta["device_count"] = jax.device_count()
    except Exception:   # noqa: BLE001 — meta must never sink a bench run
        pass
    return meta


def _parse_derived(derived: str) -> dict:
    out = {}
    for part in derived.split(";"):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        try:
            out[k] = float(v) if "." in v or "e" in v.lower() else int(v)
        except ValueError:
            out[k] = v
    return out


def write_bench_json(suite: str, rows: dict, out_dir: str | None = None,
                     meta: dict | None = None) -> str:
    path = os.path.join(out_dir or os.path.dirname(os.path.abspath(__file__)),
                        f"BENCH_{suite}.json")
    doc = {"suite": suite, "rows": rows}
    if meta is not None:
        doc["meta"] = meta
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    return path


def run_suite(name: str, mod, emit=print, meta: dict | None = None) -> str:
    """Run one suite, tee its CSV lines to `emit`, write BENCH_<name>.json."""
    rows: dict = {}

    def tee(line: str):
        emit(line)
        parts = str(line).split(",", 2)
        if len(parts) >= 2:
            try:
                us = float(parts[1])
            except ValueError:
                return
            rows[parts[0]] = {
                "us": us,
                "derived": _parse_derived(parts[2]) if len(parts) > 2 else {},
            }

    mod.main(emit=tee)
    return write_bench_json(name, rows, meta=meta if meta is not None
                            else run_meta())


def main() -> None:
    args = [a for a in sys.argv[1:]]
    if "--smoke" in args:
        from benchmarks import smoke
        raise SystemExit(smoke.main())
    from benchmarks import (bench_distributed, bench_kernels, bench_loading,
                            bench_multiway, bench_queries, bench_selectivity,
                            bench_serving)
    mods = {
        "loading": bench_loading,
        "queries": bench_queries,
        "multiway": bench_multiway,
        "selectivity": bench_selectivity,
        "kernels": bench_kernels,
        "serving": bench_serving,
        "distributed": bench_distributed,
    }
    only = args[0] if args else None
    print("name,us_per_call,derived")
    meta = run_meta()   # one stamp for the whole invocation
    for name, mod in mods.items():
        if only and name != only:
            continue
        run_suite(name, mod, meta=meta)


if __name__ == "__main__":
    main()
