"""Kernel microbenches: Pallas (interpret-validated) entry points vs jnp.

Interpret mode is a correctness harness, not a perf surface — the numbers
here benchmark the jnp oracle path used on CPU and record problem sizes for
the TPU kernels' VMEM plans (see kernels/*.py docstrings)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.rdf import pack3


def main(emit=print, sizes=((1 << 16, 1 << 10), (1 << 20, 1 << 14))):
    rng = np.random.RandomState(0)
    for m, q in sizes:
        keys = jnp.asarray(np.sort(pack3(rng.randint(0, 1 << 20, m),
                                         rng.randint(0, 50, m),
                                         rng.randint(0, 1 << 20, m))))
        qs = jnp.asarray(pack3(rng.randint(0, 1 << 20, q),
                               rng.randint(0, 50, q),
                               rng.randint(0, 1 << 20, q)))
        f = jax.jit(lambda k, x: jnp.searchsorted(k, x))
        jax.block_until_ready(f(keys, qs))
        t0 = time.perf_counter()
        for _ in range(10):
            jax.block_until_ready(f(keys, qs))
        dt = (time.perf_counter() - t0) / 10
        emit(f"bench_kernels/searchsorted_m{m}_q{q},{dt*1e6:.0f},"
             f"probes_per_s={q/dt:.3e}")


if __name__ == "__main__":
    main()
