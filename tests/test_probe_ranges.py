"""Regression tests: composite-key [lo, hi) bounds at the field boundary.

The former ``pack3(v, w + 1, 0)`` / ``pack3(v + 1, 0, 0)`` upper bounds are
wrong when the incremented field is MAX_ID (2^21 - 1): the spill bit lands
on an already-set bit of the field above (``|`` cannot carry), silently
emptying the range, and a leading field wraps int64 negative. probe_ranges
and row_range now use saturating ``lo + (1 << shift)`` arithmetic
(plan.next_prefix); these tests pin ids 0, MAX_ID - 1, and MAX_ID.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import Pattern, build_store, execute_local, execute_oracle
from repro.core.bgp import rows_set
from repro.core.plan import make_plan, next_prefix, probe_ranges, row_range
from repro.core.rdf import BITS, INF_KEY, MAX_ID, pack3

EDGE_IDS = [0, MAX_ID - 1, MAX_ID]


def _ranges(pattern, table=None, domain=()):
    plan = make_plan(pattern, domain)
    t = table if table is not None else jnp.zeros((1, len(domain)), jnp.int32)
    lo, hi = probe_ranges(plan, t)
    return plan, np.asarray(lo), np.asarray(hi)


@pytest.mark.parametrize("v", EDGE_IDS)
def test_prefix1_range_covers_field(v):
    _, lo, hi = _ranges(Pattern(v, "?p", "?o"))
    assert hi[0] > lo[0] >= 0
    # every key with this subject is inside, the next subject's keys are not
    assert lo[0] <= int(pack3(np.int64(v), np.int64(0), np.int64(0)))
    # ... except the all-MAX key == INF_KEY, the unstorable padding sentinel
    assert int(pack3(np.int64(v), np.int64(MAX_ID),
                     np.int64(MAX_ID - 1))) < hi[0]
    if v < MAX_ID:
        assert int(pack3(np.int64(v + 1), np.int64(0), np.int64(0))) >= hi[0]
    else:
        assert hi[0] == INF_KEY  # saturated exclusive bound


@pytest.mark.parametrize("v1", EDGE_IDS)
@pytest.mark.parametrize("v0", [0, 5, MAX_ID])  # odd v0 hit the old | no-op
def test_prefix2_range_covers_field(v0, v1):
    _, lo, hi = _ranges(Pattern(v0, v1, "?o"))
    assert hi[0] > lo[0] >= 0
    top = MAX_ID - 1 if (v0, v1) == (MAX_ID, MAX_ID) else MAX_ID
    assert int(pack3(np.int64(v0), np.int64(v1), np.int64(top))) < hi[0]
    if (v0, v1) != (MAX_ID, MAX_ID):
        nxt = (v0, v1 + 1) if v1 < MAX_ID else (v0 + 1, 0)
        assert int(pack3(np.int64(nxt[0]), np.int64(nxt[1]),
                         np.int64(0))) >= hi[0]


@pytest.mark.parametrize("v", EDGE_IDS)
def test_prefix3_range_is_point(v):
    _, lo, hi = _ranges(Pattern(v, v, v))
    if v == MAX_ID:
        assert hi[0] == INF_KEY  # 2^63 - 1 saturates; still exclusive-covers
    else:
        assert hi[0] == lo[0] + 1


@pytest.mark.parametrize("v", EDGE_IDS)
def test_row_range_boundary(v):
    table = jnp.asarray([[v]], jnp.int32)
    plan = make_plan(Pattern("?y", 9, "?z"), ("?y",))
    lo, hi = row_range(plan, table)
    lo, hi = np.asarray(lo), np.asarray(hi)
    assert hi[0] > lo[0]
    assert int(pack3(np.int64(v), np.int64(MAX_ID),
                     np.int64(MAX_ID - 1))) < hi[0]


def test_next_prefix_saturates_only_on_overflow():
    lo = jnp.asarray([0, MAX_ID << (2 * BITS)], jnp.int64)
    hi = np.asarray(next_prefix(lo, 2 * BITS))
    assert hi[0] == 1 << (2 * BITS)
    assert hi[1] == INF_KEY


@pytest.mark.parametrize("v", EDGE_IDS)
def test_scan_finds_boundary_subject(v):
    tr = np.asarray([[v, 7, 3], [v, 8, 4], [(v + 1) % MAX_ID, 7, 5]],
                    np.int32)
    store = build_store(tr, 1)
    pats = [Pattern(v, "?p", "?o")]
    bnd = execute_local(store, pats)
    got = rows_set(bnd.table, bnd.valid, len(bnd.vars))
    want, ovars = execute_oracle(tr, pats)
    perm = [bnd.vars.index(x) for x in ovars]
    assert {tuple(r[i] for i in perm) for r in got} == want
    assert len(want) == 2


def test_inf_key_collision_guarded():
    """The one triple that packs to the INF_KEY padding sentinel is rejected
    at load, and dictionary encoding can never produce it (id MAX_ID is
    reserved) — so 'real keys < INF_KEY' is an enforced invariant, not an
    assumption."""
    from repro.core.rdf import Dictionary
    with pytest.raises(ValueError):
        build_store(np.asarray([[MAX_ID, MAX_ID, MAX_ID]], np.int32), 1)
    d = Dictionary()
    d._bwd = ["t"] * MAX_ID                 # ids 0..MAX_ID-1 all assigned
    with pytest.raises(ValueError):
        d.id("one-term-too-many")


def test_join_probe_at_boundary():
    """A cascade whose probe key is MAX_ID: the old hi wrapped negative and
    the GET came back empty."""
    tr = np.asarray([[1, 7, MAX_ID], [MAX_ID, 9, 4], [2, 7, 3], [3, 9, 6]],
                    np.int32)
    store = build_store(tr, 1)
    pats = [Pattern("?x", 7, "?y"), Pattern("?y", 9, "?z")]
    bnd = execute_local(store, pats)
    got = rows_set(bnd.table, bnd.valid, len(bnd.vars))
    want, ovars = execute_oracle(tr, pats)
    perm = [bnd.vars.index(x) for x in ovars]
    assert {tuple(r[i] for i in perm) for r in got} == want
    assert (1, MAX_ID, 4) in want
