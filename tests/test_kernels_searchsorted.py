"""Pallas searchsorted kernel (interpret) vs oracle — shape/dtype sweep."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip; the suite still runs
    from _hypothesis_stub import given, settings, st

from repro.core.rdf import pack3
from repro.kernels import ops
from repro.kernels.searchsorted import searchsorted3


@pytest.mark.parametrize("m,q", [(1, 1), (100, 7), (1000, 257), (5000, 333),
                                 (65536, 1024)])
def test_packed_sweep(m, q, rng):
    keys = np.sort(pack3(rng.randint(0, 2000, m), rng.randint(0, 50, m),
                         rng.randint(0, 2000, m)))
    qs = pack3(rng.randint(0, 2100, q), rng.randint(0, 55, q),
               rng.randint(0, 2100, q))
    import jax.numpy as jnp
    got = np.asarray(ops.searchsorted(jnp.asarray(keys), jnp.asarray(qs)))
    want = np.searchsorted(keys, qs)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("block_k,block_q", [(64, 16), (256, 64), (2048, 256)])
def test_block_shapes(block_k, block_q, rng):
    m, q = 3000, 100
    k3 = np.sort(rng.randint(0, 500, (m, 3)).astype(np.int32).view(np.int32), axis=0)
    # build lexicographically sorted rows properly
    rows = rng.randint(0, 500, (m, 3)).astype(np.int32)
    order = np.lexsort((rows[:, 2], rows[:, 1], rows[:, 0]))
    rows = rows[order]
    qs = rng.randint(0, 550, (q, 3)).astype(np.int32)
    import jax.numpy as jnp
    got = np.asarray(searchsorted3(jnp.asarray(rows), jnp.asarray(qs),
                                   block_k=block_k, block_q=block_q,
                                   interpret=True))
    packed = (rows[:, 0].astype(np.int64) << 42) | \
             (rows[:, 1].astype(np.int64) << 21) | rows[:, 2]
    pq = (qs[:, 0].astype(np.int64) << 42) | \
         (qs[:, 1].astype(np.int64) << 21) | qs[:, 2]
    want = np.searchsorted(packed, pq)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), m=st.integers(1, 700),
       q=st.integers(1, 130))
def test_property_vs_oracle(seed, m, q):
    rng = np.random.RandomState(seed)
    keys = np.sort(pack3(rng.randint(0, 80, m), rng.randint(0, 8, m),
                         rng.randint(0, 80, m)))
    qs = pack3(rng.randint(0, 90, q), rng.randint(0, 9, q),
               rng.randint(0, 90, q))
    import jax.numpy as jnp
    got = np.asarray(ops.searchsorted(jnp.asarray(keys), jnp.asarray(qs),
                                      block_k=64, block_q=32))
    np.testing.assert_array_equal(got, np.searchsorted(keys, qs))


def test_boundary_duplicates():
    """Duplicate keys + probes hitting exact boundaries ('left' semantics)."""
    import jax.numpy as jnp
    keys = np.array([5, 5, 5, 7, 7, 9], np.int64)
    qs = np.array([4, 5, 6, 7, 8, 9, 10], np.int64)
    got = np.asarray(ops.searchsorted(jnp.asarray(keys), jnp.asarray(qs),
                                      block_k=64, block_q=32))
    np.testing.assert_array_equal(got, np.searchsorted(keys, qs))
