"""Observability layer (DESIGN.md §8): query-lifecycle span tracing with
Chrome/Perfetto trace-event export, the process metrics registry
(counters / gauges / fixed-bucket histograms with Prometheus text), and
their wiring through ServeEngine — including the span tree of a
multi-rung escalated query, the detect -> retry -> clean-epoch shape of
a seeded fault run, the metrics-off guarantee (global registry untouched
when disabled), explain()'s estimated-vs-actual drift column, and the
`repro.serve` lifecycle logger (silent at the default WARNING level)."""
import json
import logging

import numpy as np
import pytest

from repro.core import (Caps, ExecConfig, Pattern, build_store,
                        compile_plan, execute_local, explain)
from repro.obs import (DEFAULT_LATENCY_BUCKETS, NULL_REGISTRY, REGISTRY,
                       Histogram, MetricsRegistry, Tracer)
from repro.obs.trace import load_chrome, validate_events
from repro.serve import Fault, FaultPlan, ServeEngine

TINY = Caps(scan_cap=4096, out_cap=8, probe_cap=2, row_cap=4)
CHAIN = [Pattern("?x", 101, "?y"), Pattern("?y", 102, "?z")]


def random_graph(rng, n=500, subjects=40, preds=5, objects=40):
    return np.stack([rng.randint(0, subjects, n),
                     rng.randint(100, 100 + preds, n),
                     rng.randint(0, objects, n)], 1).astype(np.int32)


def _mesh1():
    import jax
    from jax.sharding import Mesh
    return Mesh(np.array(jax.devices()[:1]), ("data",))


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_histogram_bucket_boundaries():
    h = Histogram((1.0, 2.0, 4.0))
    # observation equal to a bound lands in that bound's bucket (le=bound)
    for v in (0.5, 1.0, 1.5, 2.0, 4.0, 99.0):
        h.observe(v)
    # counts per bucket: le=1 -> {0.5, 1.0}; le=2 -> {1.5, 2.0};
    # le=4 -> {4.0}; +inf -> {99.0}
    assert list(h.counts) == [2, 2, 1, 1]
    assert h.count == 6 and h.sum == pytest.approx(108.0)
    cum = h.cumulative()
    assert cum == [(1.0, 2), (2.0, 4), (4.0, 5), (float("inf"), 6)]
    # +inf terminal bucket is appended automatically and exactly once
    assert h.bounds[-1] == float("inf") and h.bounds[:-1] == (1.0, 2.0, 4.0)


def test_histogram_quantiles_interpolate():
    h = Histogram((10.0, 20.0, 40.0))
    for _ in range(50):
        h.observe(5.0)     # le=10
    for _ in range(50):
        h.observe(15.0)    # le=20
    assert h.quantile(0.5) == pytest.approx(10.0, rel=0.05)
    assert 10.0 < h.quantile(0.9) <= 20.0
    # the +inf bucket has no upper edge: quantiles falling there report
    # the observed max instead of infinity
    h.observe(1e6)
    assert h.quantile(0.999) == pytest.approx(1e6)
    assert h.quantile(0.0) <= h.quantile(1.0)


def test_histogram_rejects_bad_bounds():
    with pytest.raises(ValueError):
        Histogram((2.0, 1.0))
    with pytest.raises(ValueError):
        Histogram((1.0, 1.0))


def test_registry_instruments_and_labels():
    reg = MetricsRegistry()
    reg.counter("reqs_total", tenant="a").inc()
    reg.counter("reqs_total", tenant="a").inc(2)
    reg.counter("reqs_total", tenant="b").inc()
    reg.gauge("depth").set(7)
    reg.histogram("lat_seconds").observe(0.01)
    d = reg.to_dict()
    assert d["counters"]['reqs_total{tenant="a"}'] == 3
    assert d["counters"]['reqs_total{tenant="b"}'] == 1
    assert d["gauges"]["depth"] == 7
    assert d["histograms"]["lat_seconds"]["count"] == 1
    # one name = one instrument kind, enforced
    with pytest.raises(ValueError):
        reg.gauge("reqs_total")
    # prometheus text exposition: cumulative le= buckets + sum/count
    text = reg.to_prom_text()
    assert 'reqs_total{tenant="a"} 3' in text
    assert 'le="+Inf"' in text and "lat_seconds_count 1" in text


def test_registry_hooks_fire_on_tick():
    reg = MetricsRegistry()
    seen = []
    reg.add_hook(10.0, lambda r: seen.append(r.to_dict()))
    assert reg.tick(now=0.0) == 0      # first tick arms, does not fire
    assert reg.tick(now=5.0) == 0      # interval not yet elapsed
    assert reg.tick(now=11.0) == 1
    assert reg.tick(now=12.0) == 0
    assert reg.tick(now=25.0) == 1
    assert len(seen) == 2 and isinstance(seen[0], dict)


def test_null_registry_is_inert():
    NULL_REGISTRY.counter("x").inc()
    NULL_REGISTRY.gauge("y", a="b").set(3)
    NULL_REGISTRY.histogram("z").observe(1.0)
    assert NULL_REGISTRY.tick() == 0
    assert NULL_REGISTRY.to_dict() == {"counters": {}, "gauges": {},
                                       "histograms": {}}


def test_default_latency_buckets_ascend():
    bs = DEFAULT_LATENCY_BUCKETS
    assert all(a < b for a, b in zip(bs, bs[1:]))
    assert bs[0] <= 1e-4 and bs[-1] == float("inf")


# ---------------------------------------------------------------------------
# tracer + chrome export
# ---------------------------------------------------------------------------


def test_tracer_span_nesting_and_double_end():
    tr = Tracer()
    with tr.span("outer") as o:
        with tr.span("inner"):
            pass
    inner = tr.find("inner")[0]
    assert inner.parent_id == o.span_id and inner.t1 >= inner.t0
    with pytest.raises(ValueError):
        tr.end(o)                      # already ended by the ctx manager
    assert tr.open_count == 0


def test_trace_json_round_trips(tmp_path):
    tr = Tracer()
    root = tr.begin("query", track="query", async_id=7, tenant="t0")
    child = tr.begin("queued", track="query", parent=root, async_id=7)
    tr.end(child)
    tr.end(root, outcome="ok")
    s = tr.begin("step", track="engine")
    tr.end(s, delivered=3)
    path = tmp_path / "trace.json"
    tr.export(str(path))
    events = load_chrome(str(path))    # validates schema on load
    validate_events(events)
    phs = sorted(e["ph"] for e in events)
    assert "X" in phs and "b" in phs and "e" in phs and "M" in phs
    # async b/e events carry the query id so Perfetto nests them per query
    bs = [e for e in events if e["ph"] == "b"]
    assert all(e["id"] == 7 for e in bs)
    # attrs survive the round trip
    x = [e for e in events if e["ph"] == "X"][0]
    assert x["args"]["delivered"] == 3
    raw = json.loads(path.read_text())
    assert set(raw) == {"traceEvents", "displayTimeUnit"}


def test_validate_events_catches_unbalanced_async():
    bad = [{"ph": "b", "pid": 1, "tid": 1, "ts": 0, "cat": "q", "id": 1,
            "name": "x"}]
    with pytest.raises(ValueError, match="unbalanced"):
        validate_events(bad)


def test_coverage_merges_overlaps():
    tr = Tracer(clock=lambda: 0.0)
    tr.record("a", 0.0, 0.6)
    tr.record("b", 0.4, 0.8)           # overlaps a: union is [0, 0.8]
    tr.record("c", 0.9, 1.0)
    assert tr.coverage(0.0, 1.0) == pytest.approx(0.9)
    assert tr.coverage(0.0, 0.5) == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# engine wiring: span tree of a multi-rung escalated query
# ---------------------------------------------------------------------------


def test_escalated_query_span_tree(rng):
    store = build_store(random_graph(rng), 1)
    tr = Tracer()
    reg = MetricsRegistry()
    eng = ServeEngine(store, caps=TINY, max_escalations=3, tracer=tr,
                      metrics=reg)
    res = eng.execute([CHAIN])[0]
    assert tr.open_count == 0, [s.name for s in tr.open_spans()]
    by_id = {s.span_id: s for s in tr.spans}
    # exactly one root query span, ended with an outcome
    roots = tr.find("query")
    assert len(roots) == 1
    root = roots[0]
    assert root.attrs["outcome"] == "ok" and root.attrs["n_patterns"] == 2
    # every query-track span hangs off the root (directly or via a rung)
    for s in tr.spans:
        if s.track == "query" and s is not root:
            p = s
            while p.parent_id is not None:
                p = by_id[p.parent_id]
            assert p is root, s.name
            assert s.async_id == root.async_id   # one Perfetto lane
    # the rung ladder: rung0..rungN-1 escalate, the last one falls back
    rungs = sorted((s for s in tr.spans if s.name.startswith("rung")),
                   key=lambda s: s.attrs["attempt"])
    assert len(rungs) >= 2
    assert all(s.attrs["outcome"] == "escalate" for s in rungs[:-1])
    assert rungs[-1].attrs["outcome"] in ("escalate", "fallback")
    # out_cap strictly escalates along the ladder
    caps_seq = [s.attrs["out_cap"] for s in rungs]
    assert caps_seq == sorted(set(caps_seq))
    if rungs[-1].attrs["outcome"] == "fallback":
        fb = tr.find("exact_fallback")
        assert fb, "fallback leg must be traced"
        # the exact run's per-cascade-step work hangs under the leg
        steps = [s for s in tr.spans if s.name.startswith("cascade_step")
                 and s.parent_id == fb[0].span_id]
        assert steps and all(s.attrs.get("kind") for s in steps)
    # each dispatch ran under a step span on the engine track
    for d in tr.find("dispatch"):
        assert by_id[d.parent_id].name == "step"
    # registry saw the same story the spans tell
    snap = reg.to_dict()
    assert snap["counters"]["serve_escalations_total"] == len(rungs) - 1
    assert snap["counters"]["serve_dispatches_total"] == len(
        tr.find("dispatch"))
    assert res.rows.shape[1] == 3     # ?x ?y ?z — the query still answers


def test_engine_trace_exports_loadable_json(rng, tmp_path):
    store = build_store(random_graph(rng), 1)
    tr = Tracer()
    eng = ServeEngine(store, caps=TINY, max_escalations=3, tracer=tr,
                      metrics=MetricsRegistry())
    eng.execute([CHAIN])
    path = tmp_path / "TRACE.json"
    tr.export(str(path))
    events = load_chrome(str(path))
    names = {e["name"] for e in events}
    assert {"query", "submit", "step", "dispatch"} <= names


# ---------------------------------------------------------------------------
# metrics-off guarantee + per-tenant SLO counters
# ---------------------------------------------------------------------------


def test_global_registry_untouched_when_disabled(rng):
    store = build_store(random_graph(rng), 1)
    before = REGISTRY.to_dict()
    eng = ServeEngine(store, caps=TINY, max_escalations=3, metrics=False)
    eng.execute([CHAIN])
    assert REGISTRY.to_dict() == before
    # and the accessor still answers (empty) instead of exploding
    assert eng.metrics() == {"counters": {}, "gauges": {}, "histograms": {}}


def test_per_tenant_latency_histograms(rng):
    store = build_store(random_graph(rng), 1)
    reg = MetricsRegistry()
    eng = ServeEngine(store, caps=Caps(out_cap=128, probe_cap=32, row_cap=16),
                      metrics=reg, max_escalations=0)
    for tenant in ("alpha", "alpha", "beta"):
        eng.submit(CHAIN, arrival=0.0, tenant=tenant)
        eng.step(now=1.0)
    h = reg.to_dict()["histograms"]
    a = h['serve_tenant_latency_seconds{tenant="alpha"}']
    b = h['serve_tenant_latency_seconds{tenant="beta"}']
    assert a["count"] == 2 and b["count"] == 1
    assert a["p99"] >= a["p50"] > 0
    assert any(k.startswith("serve_template_latency_seconds") for k in h)
    counters = reg.to_dict()["counters"]
    assert counters['serve_requests_total{tenant="alpha"}'] == 2


# ---------------------------------------------------------------------------
# fault run: detect -> retry -> clean epoch, visible in the trace
# ---------------------------------------------------------------------------


def test_fault_run_trace_shows_detect_retry_clean(rng):
    store = build_store(random_graph(rng), 1)
    fp = FaultPlan((Fault(0, 0, "drop", epoch=0),
                    Fault(0, 0, "corrupt", epoch=1)))
    tr = Tracer()
    reg = MetricsRegistry()
    eng = ServeEngine(store, cfg=ExecConfig(routing="a2a"),
                      caps=Caps(out_cap=4096, probe_cap=16, row_cap=64),
                      mesh=_mesh1(), fault_plan=fp, tracer=tr, metrics=reg)
    res = eng.execute([CHAIN])[0]
    disp = sorted(tr.find("dispatch"), key=lambda s: s.t0)
    assert len(disp) >= 3              # two poisoned epochs + one clean
    assert disp[0].attrs["bad"] > 0 and disp[1].attrs["bad"] > 0
    assert disp[-1].attrs["bad"] == 0  # recovered on a clean epoch
    epochs = [s.attrs["epoch"] for s in disp]
    assert epochs == sorted(epochs) and len(set(epochs)) == len(epochs)
    assert all(s.attrs["retry"] == i for i, s in enumerate(disp[:3]))
    # the retries re-dispatched the same batch, visible in the registry
    c = reg.to_dict()["counters"]
    assert c["serve_faults_detected_total"] >= 2
    assert c["serve_fault_redispatches_total"] >= 2
    assert "serve_fault_unrecovered_total" not in c
    # the degenerate 1-shard mesh moves zero bytes over the collective
    # (s-1 == 0 peers), so the payload counters must not lie about it
    assert c.get("serve_a2a_probe_bytes_total", 0) == 0
    assert res.rows is not None


def test_a2a_leg_bytes_wire_format():
    from repro.core.distributed import a2a_leg_bytes
    probe, answer = a2a_leg_bytes(16, 8, 4)
    # probe leg: (s-1) peers x bucket_cap keyed slots of (key, tag) int64s
    assert probe == 3 * 16 * (8 + 8)
    # answer leg adds the cap-rows payload + validity/checksum words
    assert answer == 3 * 16 * (8 * 8 + 4 + 4)
    assert a2a_leg_bytes(16, 8, 1) == (0, 0)   # no peers, no traffic


# ---------------------------------------------------------------------------
# explain(): estimated vs actual; lifecycle logging
# ---------------------------------------------------------------------------


def test_explain_drift_column(rng):
    store = build_store(random_graph(rng), 1)
    plan = compile_plan(store, CHAIN, Caps(out_cap=128, probe_cap=32, row_cap=16))
    base = explain(plan)
    assert "drift" not in base         # golden no-stats text unchanged
    stats: list = []
    execute_local(store, plan, stats=stats)
    text = explain(plan, stats=stats)
    assert "drift=x" in text and "wall=" in text
    assert "actual=" in text and "est cost" in text
    # the no-stats render is untouched by an instrumented run existing
    assert explain(plan) == base


def test_serve_logger_lifecycle_events(rng, caplog):
    store = build_store(random_graph(rng), 1)
    eng = ServeEngine(store, caps=TINY, max_escalations=3,
                      metrics=MetricsRegistry())
    with caplog.at_level(logging.DEBUG, logger="repro.serve"):
        eng.execute([CHAIN])
    msgs = [r.message for r in caplog.records]
    assert any("admit" in m for m in msgs)
    assert any("escalat" in m for m in msgs)
    # off by default: the logger inherits WARNING and adds no handlers
    lg = logging.getLogger("repro.serve")
    assert lg.handlers == [] and lg.getEffectiveLevel() >= logging.WARNING
