"""Calibrate the analytic roofline cost model against XLA cost analysis.

XLA's cost analysis counts while-loop bodies once (the reason the model
exists — see launch/costmodel.py). On configs where nothing loops — naive
attention, remat off, microbatch 1, depth-delta between two unrolled-free
models — XLA is exact, so the per-layer flops DELTA must match the model.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.configs.base import ShapeConfig
from repro.launch.costmodel import (_fwd_flops_per_token, cost_cell)
from repro.models import build_model, input_defs, make_prefill_step
from repro.models.params import abstract_tree


def xla_flops(cfg, shape, rng):
    """fwd+bwd flops of the loss on an UNROLLED (scan_layers=False) model —
    the loop-free case where XLA cost analysis is exact."""
    model = build_model(cfg)
    from repro.models.params import init_tree
    params = abstract_tree(model.param_defs())
    batch = abstract_tree(input_defs(cfg, shape))

    def loss_grads(p, b):
        (l, _), g = jax.value_and_grad(model.loss, has_aux=True)(p, b)
        return l, g

    comp = jax.jit(loss_grads).lower(params, batch).compile()
    ca = comp.cost_analysis()
    if isinstance(ca, (list, tuple)):  # older JAX: one dict per device
        ca = ca[0]
    return float(ca["flops"])


@pytest.mark.parametrize("arch", ["yi-6b", "qwen3-8b"])
def test_per_layer_flops_calibration(arch, rng):
    base = reduce_for_smoke(get_config(arch))
    shape = ShapeConfig("t", 64, 2, "train")
    # naive attention + unrolled layers => no loops anywhere; the depth
    # delta isolates exactly one layer's fwd+bwd flops
    mk = lambda L: dataclasses.replace(base, num_layers=L,
                                       attention_impl="naive",
                                       remat_policy="none",
                                       scan_layers=False)
    f2, f4 = xla_flops(mk(2), shape, rng), xla_flops(mk(4), shape, rng)
    xla_per_layer = (f4 - f2) / 2
    tokens = shape.global_batch * shape.seq_len
    cfg4, cfg2 = mk(4), mk(2)
    # analytic: fwd x 3 (bwd = 2x fwd) with remat none
    ana_per_layer = (_fwd_flops_per_token(cfg4, shape.seq_len)
                     - _fwd_flops_per_token(cfg2, shape.seq_len)) / 2 \
        * tokens * 3.0
    ratio = ana_per_layer / xla_per_layer
    assert 0.7 < ratio < 1.4, f"{arch}: analytic/xla per-layer = {ratio:.3f}"


def test_cost_cell_terms_sane():
    cfg = get_config("yi-6b")
    from repro.configs.base import SHAPES
    cost = cost_cell(cfg, SHAPES["train_4k"], {"data": 16, "model": 16},
                     micro_batches=16)
    terms = cost.terms(256)
    assert 0 < terms["useful_ratio"] <= 1.0
    assert terms["dominant"] in ("compute", "memory", "collective")
    assert cost.model_flops == pytest.approx(
        6 * cfg.n_params() * 256 * 4096, rel=1e-6)
    # decode must be memory-bound (weight streaming)
    dec = cost_cell(cfg, SHAPES["decode_32k"], {"data": 16, "model": 16})
    assert dec.terms(256)["dominant"] == "memory"


def test_moe_active_params():
    cfg = get_config("deepseek-v3-671b")
    assert cfg.n_params() > 600e9
    assert cfg.n_active_params() < 50e9  # ~37B active
