"""Per-architecture smoke tests: REDUCED config of the same family, one
forward/train step on CPU, asserting output shapes + no NaNs — plus
prefill->decode consistency (the serving path equals the training forward).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, reduce_for_smoke
from repro.models import (build_model, make_decode_step, make_prefill_step,
                          make_train_step)
from repro.models.params import init_tree
from repro.optim import OptConfig, init_opt_state

from conftest import make_lm_batch

pytestmark = pytest.mark.slow  # minutes: every arch compiles a train step

ARCHS = list_archs()
S, B = 64, 2


def setup(arch, rng):
    cfg = reduce_for_smoke(get_config(arch))
    model = build_model(cfg)
    params = init_tree(model.param_defs(), jax.random.key(0))
    batch = make_lm_batch(cfg, B, S, rng)
    return cfg, model, params, batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch, rng):
    cfg, model, params, batch = setup(arch, rng)
    opt = OptConfig()
    step = jax.jit(make_train_step(model, opt))
    p2, s2, m = step(params, init_opt_state(params, opt), batch)
    assert np.isfinite(float(m["loss"])), arch
    assert float(m["grad_norm"]) > 0
    # params changed and stayed finite
    changed = any(not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
                  for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert changed
    for leaf in jax.tree.leaves(p2):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_shapes(arch, rng):
    cfg, model, params, batch = setup(arch, rng)
    logits, cache = jax.jit(make_prefill_step(model))(params, batch)
    if cfg.family == "audio":
        assert logits.shape == (B, cfg.num_codebooks, cfg.vocab_size)
        tok = jnp.zeros((B, 1, cfg.num_codebooks), jnp.int32)
    else:
        assert logits.shape == (B, cfg.vocab_size)
        tok = jnp.zeros((B, 1), jnp.int32)
    logits2, cache2 = jax.jit(make_decode_step(model))(params, cache,
                                                       {"tokens": tok})
    assert logits2.shape == logits.shape
    assert np.isfinite(np.asarray(logits2, np.float32)).all(), arch
    assert int(cache2["cur_len"]) == int(cache["cur_len"]) + 1


@pytest.mark.parametrize("arch", ["yi-6b", "qwen3-8b", "deepseek-v3-671b",
                                  "xlstm-125m", "recurrentgemma-9b",
                                  "musicgen-large"])
def test_decode_consistency(arch, rng):
    """Teacher forcing: prefill(s) + decode(tok_s) == prefill(s+1)."""
    cfg, model, params, _ = setup(arch, rng)
    if cfg.family == "audio":
        toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S + 1, cfg.num_codebooks)),
                           jnp.int32)
    else:
        toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S + 1)), jnp.int32)
    _, cache = jax.jit(make_prefill_step(model))({**params}, {"tokens": toks[:, :S]})
    step_tok = toks[:, S:S + 1]
    got, _ = jax.jit(make_decode_step(model))(params, cache, {"tokens": step_tok})
    # decode caches hold only `window` history for windowed archs — extend
    # the reference prefill accordingly (still exact: window covers S+1)
    want, _ = jax.jit(make_prefill_step(model))(params, {"tokens": toks})
    err = float(jnp.max(jnp.abs(got.astype(jnp.float32) - want.astype(jnp.float32))))
    assert err < 2e-3, f"{arch}: decode diverges from prefill ({err})"
