"""Pallas flash attention (interpret) + XLA blockwise impls vs reference."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.models import attention as attn


CASES = [
    # (b, sq, skv, h, g, e, causal)
    (2, 128, 128, 4, 4, 64, True),
    (1, 256, 256, 8, 2, 32, True),
    (2, 96, 160, 4, 1, 16, False),
    (1, 64, 64, 2, 2, 128, True),
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_flash_vs_ref(case, dtype, rng):
    b, sq, skv, h, g, e, causal = case
    if causal and sq != skv:
        pytest.skip("kernel causal mask assumes aligned sq == skv")
    q = jnp.asarray(rng.randn(b, sq, h, e), dtype)
    k = jnp.asarray(rng.randn(b, skv, g, e), dtype)
    v = jnp.asarray(rng.randn(b, skv, g, e), dtype)
    got = ops.flash_attention(q, k, v, causal=causal, block_q=64, block_kv=64)
    want = ref.attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    assert float(jnp.max(jnp.abs(got.astype(jnp.float32) -
                                 want.astype(jnp.float32)))) < tol


@pytest.mark.parametrize("impl", ["xla", "xla_tri"])
@pytest.mark.parametrize("case", CASES)
def test_xla_blockwise_vs_naive(impl, case, rng):
    b, sq, skv, h, g, e, causal = case
    q = jnp.asarray(rng.randn(b, sq, h, e), jnp.float32)
    k = jnp.asarray(rng.randn(b, skv, g, e), jnp.float32)
    v = jnp.asarray(rng.randn(b, skv, g, e), jnp.float32)
    got = attn.attention(q, k, v, impl=impl, causal=causal, block_q=32,
                         block_kv=32)
    want = attn.naive_attention(q, k, v, causal=causal)
    assert float(jnp.max(jnp.abs(got - want))) < 2e-5


def test_local_window_vs_naive(rng):
    b, s, h, g, e, w = 2, 128, 4, 1, 32, 48
    q = jnp.asarray(rng.randn(b, s, h, e), jnp.float32)
    k = jnp.asarray(rng.randn(b, s, g, e), jnp.float32)
    v = jnp.asarray(rng.randn(b, s, g, e), jnp.float32)
    got = attn.local_attention(q, k, v, window=w, block_q=32)
    want = attn.naive_attention(q, k, v, causal=True, window=w)
    assert float(jnp.max(jnp.abs(got - want))) < 2e-5


def test_decode_matches_prefill_row(rng):
    """decode_attention(q_t, cache) == last row of full causal attention."""
    b, s, h, g, e = 2, 33, 4, 2, 16
    q = jnp.asarray(rng.randn(b, s, h, e), jnp.float32)
    k = jnp.asarray(rng.randn(b, s, g, e), jnp.float32)
    v = jnp.asarray(rng.randn(b, s, g, e), jnp.float32)
    full = attn.naive_attention(q, k, v, causal=True)
    one = attn.decode_attention(q[:, -1:], k, v, cur_len=s)
    assert float(jnp.max(jnp.abs(one[:, 0] - full[:, -1]))) < 2e-5
