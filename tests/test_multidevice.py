"""Multi-device tests — run in a subprocess with 8 forced host devices so
the main pytest process keeps its single-device view (per assignment, the
device-count flag must never be set globally)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow  # minutes: each test spawns an 8-device subprocess

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_in_subprocess(code: str) -> dict:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_sharded_join_vs_oracle():
    res = run_in_subprocess(textwrap.dedent("""
        import json, numpy as np, jax
        from jax.sharding import Mesh
        from repro.core import (Caps, Pattern, build_store, execute_sharded,
                                execute_oracle, rows_set)
        mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
        rng = np.random.RandomState(3)
        tr = np.stack([rng.randint(0, 60, 600), rng.randint(100, 105, 600),
                       rng.randint(0, 60, 600)], 1).astype(np.int32)
        store = build_store(tr, num_shards=8)
        pats = [Pattern("?x", 101, "?y"), Pattern("?y", 102, "?z")]
        want, ovars = execute_oracle(tr, pats)
        ok = True
        for mode in ("mapsin", "reduce"):
            caps = Caps(out_cap=2048, probe_cap=32, bucket_cap=1024)
            t, v, ovf, vars_ = execute_sharded(store, pats, mesh, mode,
                                               caps=caps)
            got = rows_set(t, v, len(vars_))
            if vars_ != ovars:
                perm = [vars_.index(x) for x in ovars]
                got = set(tuple(r[i] for i in perm) for r in got)
            ok = ok and (got == want) and int(np.asarray(ovf).sum()) == 0
        print(json.dumps({"ok": ok, "n": len(want)}))
    """))
    assert res["ok"] and res["n"] > 0


def test_sharded_a2a_matches_broadcast():
    """routing="a2a" (point-to-point all_to_all dispatch) is bit-identical
    to the broadcast reference on an 8-shard mesh — including a fat
    rdf:type-style row whose range spans >= 2 region splits, exercising the
    multi-destination fan-out and the shard-order offset composition, and a
    star query taking the multiway single-row-GET path."""
    res = run_in_subprocess(textwrap.dedent("""
        import json, numpy as np, jax
        from jax.sharding import Mesh
        from repro.core import (Caps, ExecConfig, Pattern, build_store,
                                execute_sharded, execute_oracle, rows_set)
        from repro.core.rdf import BITS, pack3
        from repro.core.triple_store import range_intersects_region
        mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
        rng = np.random.RandomState(3)
        HUB = 70
        tr = np.stack([rng.randint(0, 60, 600), rng.randint(100, 105, 600),
                       rng.randint(0, 60, 600)], 1).astype(np.int32)
        fat = np.stack([np.full(300, HUB), np.full(300, 102),
                        np.arange(300) % 90], 1).astype(np.int32)
        link = np.stack([rng.randint(0, 60, 200), np.full(200, 101),
                         np.full(200, HUB)], 1).astype(np.int32)
        tr = np.concatenate([tr, fat, link])
        store = build_store(tr, num_shards=8)
        lo = pack3(np.int64(HUB), np.int64(0), np.int64(0))
        sp = np.asarray(store.splits_spo)
        spans = int(range_intersects_region(lo, lo + (1 << (2 * BITS)),
                                            sp[:-1], sp[1:]).sum())
        queries = [
            [Pattern("?x", 101, "?y"), Pattern("?y", 102, "?z")],   # fat probe
            [Pattern("?x", 101, "?y"), Pattern("?y", 102, "?z"),
             Pattern("?y", 103, "?w")],                              # multiway
        ]
        ok, total = True, 0
        for pats in queries:
            want, ovars = execute_oracle(tr, pats)
            got = {}
            for routing in ("broadcast", "a2a"):
                caps = Caps(out_cap=1 << 13, probe_cap=512, row_cap=512,
                            bucket_cap=1024)
                t, v, ovf, vars_ = execute_sharded(store, pats, mesh,
                                                   "mapsin",
                                                   ExecConfig(routing=routing),
                                                   caps=caps)
                perm = [vars_.index(x) for x in ovars]
                got[routing] = {tuple(r[i] for i in perm)
                                for r in rows_set(t, v, len(vars_))}
                ok = ok and int(np.asarray(ovf).sum()) == 0
            ok = ok and got["a2a"] == got["broadcast"] == want
            total += len(want)
        print(json.dumps({"ok": ok, "spans": spans, "n": total}))
    """))
    assert res["spans"] >= 2, res
    assert res["ok"] and res["n"] > 0, res


def test_sharded_batched_serving_matches_local():
    """PR 4 tentpole: ServeEngine bound to an 8-device mesh executes each
    shape bucket as ONE shard_map dispatch (routing="a2a", auto-tuned
    buckets) against the region-sharded store — every batched result must
    be row-identical to execute_local, with batching actually happening
    (dispatches == number of templates, not of queries) and zero
    overflow."""
    res = run_in_subprocess(textwrap.dedent("""
        import json, numpy as np, jax
        from jax.sharding import Mesh
        from repro.core import (Caps, ExecConfig, Pattern, build_store,
                                execute_local, rows_set)
        from repro.serve import ServeEngine
        mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
        rng = np.random.RandomState(5)
        tr = np.stack([rng.randint(0, 60, 800), rng.randint(100, 105, 800),
                       rng.randint(0, 60, 800)], 1).astype(np.int32)
        store = build_store(tr, num_shards=8)
        cfg = ExecConfig(routing="a2a")
        caps = Caps(out_cap=2048, probe_cap=64, row_cap=64)
        eng = ServeEngine(store, cfg=cfg, caps=caps, mesh=mesh, max_batch=8)
        queries = []
        for c in (1, 5, 9, 13, 17, 21):           # join template
            queries.append([Pattern("?x", 101, c), Pattern("?x", 102, "?y")])
        for c in (2, 7, 11):                      # bound-subject template
            queries.append([Pattern(c, 103, "?a"), Pattern("?a", 104, "?b")])
        for c in (3, 8):                          # multiway star template
            queries.append([Pattern("?x", 101, c), Pattern("?x", 102, "?a"),
                            Pattern("?x", 103, "?b")])
        results = eng.execute(queries)
        store1 = build_store(tr, 1)
        ok, n = True, 0
        for pats, r in zip(queries, results):
            bnd = execute_local(store1, pats, "mapsin", caps=caps)
            want = rows_set(bnd.table, bnd.valid, len(bnd.vars))
            ok = ok and r.rows_set(tuple(bnd.vars)) == want
            ok = ok and r.overflow == 0
            n += len(want)
        print(json.dumps({"ok": ok, "n": n, "dispatches": eng.dispatches,
                          "payload": eng.a2a_payload_bytes}))
    """))
    assert res["ok"] and res["n"] > 0, res
    assert res["dispatches"] == 3, res            # one per template
    assert res["payload"] > 0, res                # a2a traffic was accounted


def test_chaos_suite_8dev_faults_detected_rows_exact():
    """PR 6 chaos case at real shard count: a seeded FaultPlan injects
    drops and corruptions into the 8-shard a2a answer legs across the
    epoch schedule; the answer-leg checksums must detect every one, the
    dispatch loop must retry onto clean epochs, and every delivered row
    set must be bit-identical to execute_local — zero wrong rows under
    chaos. A saturated all-epochs-faulty plan must exhaust the retry
    budget with results flagged fault_unrecovered whose rows are a
    SUBSET of the truth (quarantined, not corrupted)."""
    res = run_in_subprocess(textwrap.dedent("""
        import json, numpy as np, jax
        from jax.sharding import Mesh
        from repro.core import (Caps, ExecConfig, Pattern, build_store,
                                execute_local, rows_set)
        from repro.serve import Fault, FaultPlan, ServeEngine
        mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
        rng = np.random.RandomState(11)
        tr = np.stack([rng.randint(0, 60, 800), rng.randint(100, 105, 800),
                       rng.randint(0, 60, 800)], 1).astype(np.int32)
        store = build_store(tr, num_shards=8)
        store1 = build_store(tr, 1)
        cfg = ExecConfig(routing="a2a")
        caps = Caps(out_cap=2048, probe_cap=64, row_cap=64)
        queries = [[Pattern("?x", 101, c), Pattern("?x", 102, "?y")]
                   for c in (1, 5, 9, 13)]
        queries += [[Pattern("?x", 101, "?y"), Pattern("?y", 102, "?z")]]
        # seeded plan, high rate so several epochs are actually faulty
        fp = FaultPlan.sample(3, num_shards=8, n_steps=1, rate=0.10,
                              horizon=16)
        assert any(fp.at(e, 0) != ((), ()) for e in range(16))
        eng = ServeEngine(store, cfg=cfg, caps=caps, mesh=mesh,
                          fault_plan=fp, fault_retries=4)
        results = eng.execute(queries)
        ok = True
        for pats, r in zip(queries, results):
            bnd = execute_local(store1, pats, "mapsin", caps=caps)
            want = rows_set(bnd.table, bnd.valid, len(bnd.vars))
            ok = ok and r.rows_set(tuple(bnd.vars)) == want
            ok = ok and "fault_unrecovered" not in (r.stats or {})
        # saturated chaos: every epoch corrupts shard 2 -> unrecoverable,
        # surviving rows still a strict subset of the truth, never wrong
        sat = FaultPlan((Fault(0, 2, "corrupt", epoch=0),), period=1)
        eng2 = ServeEngine(store, cfg=cfg, caps=caps, mesh=mesh,
                           fault_plan=sat, fault_retries=2,
                           max_escalations=0)
        r2 = eng2.execute([queries[-1]])[0]
        bnd = execute_local(store1, queries[-1], "mapsin", caps=caps)
        want = rows_set(bnd.table, bnd.valid, len(bnd.vars))
        subset = r2.rows_set(tuple(bnd.vars)) <= want
        print(json.dumps({
            "ok": ok, "detected": eng.corrupt_detected,
            "redispatches": eng.fault_redispatches,
            "unrecovered_flagged": bool(
                (r2.stats or {}).get("fault_unrecovered")),
            "subset": subset, "sat_detected": eng2.corrupt_detected}))
    """))
    assert res["ok"], res                          # zero wrong rows
    assert res["detected"] > 0, res                # faults actually fired
    assert res["redispatches"] > 0, res            # and were retried
    assert res["unrecovered_flagged"], res
    assert res["subset"], res                      # quarantine, not corruption
    assert res["sat_detected"] >= 3, res


def test_sharded_train_step_matches_single_device():
    """2x4 mesh (data x model) train step == single-device train step."""
    res = run_in_subprocess(textwrap.dedent("""
        import json, numpy as np, jax, jax.numpy as jnp
        from repro.configs import get_config, reduce_for_smoke
        from repro.configs.base import ShapeConfig
        from repro.models import build_model, make_train_step, input_defs
        from repro.models.params import init_tree, pspec_tree
        from repro.optim import OptConfig, init_opt_state
        from repro.sharding.rules import make_rules
        from repro.launch.mesh import make_mesh_for

        cfg = reduce_for_smoke(get_config("qwen3-8b"))
        shape = ShapeConfig("t", 32, 8, "train")
        rng = np.random.RandomState(0)
        batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (8, 32)), jnp.int32),
                 "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (8, 32)), jnp.int32)}
        opt = OptConfig()
        # single device
        m1 = build_model(cfg)
        p1 = init_tree(m1.param_defs(), jax.random.key(0))
        s1 = init_opt_state(p1, opt)
        q1, _, met1 = jax.jit(make_train_step(m1, opt))(p1, s1, batch)
        # 2x4 sharded
        mesh = make_mesh_for(8, model_par=4)
        rules = make_rules(mesh, cfg, shape)
        m2 = build_model(cfg, mesh, rules)
        p2 = init_tree(m2.param_defs(), jax.random.key(0))
        s2 = init_opt_state(p2, opt)
        with mesh:
            q2, _, met2 = jax.jit(make_train_step(m2, opt))(p2, s2, batch)
        dl = abs(float(met1["loss"]) - float(met2["loss"]))
        dp = max(float(np.max(np.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32))))
                 for a, b in zip(jax.tree.leaves(q1), jax.tree.leaves(q2)))
        print(json.dumps({"dloss": dl, "dparam": dp}))
    """))
    assert res["dloss"] < 1e-4, res
    assert res["dparam"] < 1e-2, res  # bf16 params, collective reduction order


def test_elastic_checkpoint_reshard():
    """Save on 1-device mesh, restore onto an 8-device mesh (and back)."""
    res = run_in_subprocess(textwrap.dedent("""
        import json, tempfile, numpy as np, jax, jax.numpy as jnp
        from repro.checkpoint import save, load, latest
        from repro.configs import get_config, reduce_for_smoke
        from repro.models import build_model
        from repro.models.params import init_tree, sharding_tree
        from repro.sharding.rules import make_rules
        from repro.launch.mesh import make_mesh_for

        cfg = reduce_for_smoke(get_config("yi-6b"))
        model = build_model(cfg)
        params = init_tree(model.param_defs(), jax.random.key(1))
        with tempfile.TemporaryDirectory() as d:
            save(d, 5, {"params": params})
            mesh = make_mesh_for(8, model_par=4)
            rules = make_rules(mesh, cfg)
            shardings = sharding_tree(build_model(cfg, mesh, rules).param_defs(), rules)
            step, out = load(latest(d), {"params": params},
                             {"params": shardings})
            ok = step == 5
            for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(out["params"])):
                ok = ok and bool(np.array_equal(np.asarray(a, np.float32),
                                                np.asarray(b, np.float32)))
                ok = ok and len(b.sharding.device_set) > 1
        print(json.dumps({"ok": ok}))
    """))
    assert res["ok"]


def test_mapsin_embedding_matches_dense():
    res = run_in_subprocess(textwrap.dedent("""
        import json, numpy as np, jax, jax.numpy as jnp
        from repro.models.embedding import dense_embed, mapsin_embed
        from repro.sharding.rules import make_rules
        from repro.launch.mesh import make_mesh_for
        mesh = make_mesh_for(8, model_par=8)
        rules = make_rules(mesh)
        rng = np.random.RandomState(0)
        table = jnp.asarray(rng.randn(64, 16), jnp.float32)
        toks = jnp.asarray(rng.randint(0, 64, (4, 10)), jnp.int32)
        with mesh:
            got = jax.jit(lambda t, x: mapsin_embed(t, x, mesh, rules))(table, toks)
        want = dense_embed(table, toks)
        err = float(jnp.max(jnp.abs(got - want)))
        print(json.dumps({"err": err}))
    """))
    assert res["err"] < 1e-6
