"""Planner IR tests (DESIGN.md §6): cost-based plans must be row-identical
to heuristic plans, to the legacy execute_local path, and to the oracle on
EVERY benchmark query; explain() renders order/operators/caps/cost; the
reduce_side fallback fires exactly when mapsin cannot answer within the
cap budget; quantize_cap holds the shared grid."""
import numpy as np
import pytest

from repro.core import (Caps, Pattern, build_store, compile_plan,
                        execute_local, execute_oracle, explain, quantize_cap,
                        rows_set)
from repro.core.planner import ENGINE_OPERATORS, LogicalPlan, relation_stats
from repro.data import lubm_like, sp2b_like

CAPS = Caps(scan_cap=1 << 15, out_cap=1 << 15, probe_cap=256, row_cap=64)


def _rows(store, bnd, ovars):
    got = rows_set(bnd.table, bnd.valid, len(bnd.vars))
    if tuple(bnd.vars) != tuple(ovars):
        perm = [bnd.vars.index(v) for v in ovars]
        got = set(tuple(r[i] for i in perm) for r in got)
    return got


@pytest.fixture(scope="module")
def lubm():
    return lubm_like(1, seed=0)


@pytest.fixture(scope="module")
def sp2b():
    return sp2b_like(400, seed=0)


def _check_cost_vs_heuristic(tr, pats):
    store = build_store(tr, 1)
    want, ovars = execute_oracle(tr, pats)
    plan_c = compile_plan(store, pats, CAPS, ordering="cost")
    plan_h = compile_plan(store, pats, CAPS, ordering="heuristic")
    assert plan_c.ordering == "cost" and plan_h.ordering == "heuristic"
    got_c = _rows(store, execute_local(store, plan_c), ovars)
    got_h = _rows(store, execute_local(store, plan_h), ovars)
    legacy = _rows(store, execute_local(store, pats, "mapsin", caps=CAPS),
                   ovars)
    assert got_c == got_h == legacy == want
    return plan_c


@pytest.mark.slow
@pytest.mark.parametrize("qname", ["Q1", "Q3", "Q4", "Q5", "Q6", "Q7", "Q8",
                                   "Q11", "Q13", "Q14"])
def test_lubm_cost_plans_row_identical(lubm, qname):
    tr, d, queries = lubm
    _check_cost_vs_heuristic(tr, queries[qname])


@pytest.mark.slow
@pytest.mark.parametrize("qname", ["Q1", "Q2", "Q3a", "Q10"])
def test_sp2b_cost_plans_row_identical(sp2b, qname):
    tr, d, queries = sp2b
    _check_cost_vs_heuristic(tr, queries[qname])


def test_cost_plans_row_identical_small(lubm):
    """Fast-tier cover: two representative queries (a star and the Q8
    chain the old probe_cap=16 bug lived in)."""
    tr, d, queries = lubm
    for q in ("Q4", "Q8"):
        _check_cost_vs_heuristic(tr, queries[q])


# ---------------------------------------------------------------------------
# explain()
# ---------------------------------------------------------------------------


def test_explain_golden():
    tr = np.array([[1, 10, 2], [1, 10, 3], [2, 11, 4], [3, 11, 4],
                   [5, 10, 2]], np.int32)
    store = build_store(tr, 1)
    pats = [Pattern("?x", 10, 2), Pattern("?x", 11, "?y")]
    caps = Caps(scan_cap=64, out_cap=64, probe_cap=8, row_cap=8)
    plan = compile_plan(store, pats, caps)
    want = """\
PhysicalPlan: 2 steps, ordering=cost, est_cost=6, vars=(?x, ?y)
  [0] scan        {?x <10> <2>}  est_out=2  caps: out=64
  [1] mapsin      {?x <11> ?y}  est_in=2 est_out=2 fanout_max=1  caps: probe=8 out=64 a2a=0"""
    assert explain(plan) == want


def test_explain_reports_overflow(lubm):
    """Satellite: undersized caps are REPORTED per step (the Q8
    probe_cap=16 class of bug), never silently dropped."""
    tr, d, queries = lubm
    store = build_store(tr, 1)
    tiny = Caps(scan_cap=1 << 15, out_cap=1 << 13, probe_cap=16, row_cap=64)
    # restrict to mapsin so the fallback cannot rescue the truncation
    plan = compile_plan(store, queries["Q8"], tiny,
                        operators=ENGINE_OPERATORS)
    stats: list = []
    bnd = execute_local(store, plan, stats=stats)
    assert int(np.asarray(bnd.overflow)) > 0
    text = explain(plan, stats=stats)
    assert "overflow=" in text and "rows dropped by capacity" in text
    per_step = [st["overflow"] for st in stats]
    assert sum(per_step) == int(np.asarray(bnd.overflow))
    assert any(o > 0 for o in per_step)


def test_explain_decodes_terms(lubm):
    tr, d, queries = lubm
    store = build_store(tr, 1)
    plan = compile_plan(store, queries["Q5"], CAPS)
    text = explain(plan, decode=d.term)
    assert "<Dept0.U0>" in text and "<Student>" in text


# ---------------------------------------------------------------------------
# reduce_side fallback
# ---------------------------------------------------------------------------


def test_reduce_side_fallback_on_residual_only_join(rng):
    """A join variable bindable only in a residual (predicate) position:
    the index GET degenerates to a full-range scan truncated at
    probe_cap, so the planner must select reduce_side — and be exact
    where the forced-mapsin plan drops rows."""
    tr = np.stack([rng.randint(0, 30, 400), rng.randint(100, 110, 400),
                   rng.randint(0, 30, 400)], 1).astype(np.int32)
    store = build_store(tr, 1)
    pats = [Pattern(3, "?p", "?o"), Pattern("?x", "?p", "?y")]
    caps = Caps(scan_cap=4096, out_cap=1 << 14, probe_cap=8, row_cap=8)
    plan = compile_plan(store, pats, caps)
    kinds = [st.kind for st in plan.steps]
    assert "reduce_side" in kinds, kinds
    want, ovars = execute_oracle(tr, pats)
    got = _rows(store, execute_local(store, plan), ovars)
    assert got == want and len(want) > 0
    # the forced-mapsin plan truncates (and surfaces it as overflow)
    forced = compile_plan(store, pats, caps, operators=ENGINE_OPERATORS)
    bnd = execute_local(store, forced)
    assert _rows(store, bnd, ovars) != want
    assert int(np.asarray(bnd.overflow)) > 0


def test_reduce_side_fallback_on_blown_probe_cap(rng):
    """Fan-out beyond probe_cap (the rdf:type hub): the planner switches
    the step to reduce_side with a right-sized sort-merge budget instead
    of silently truncating the GET."""
    hub = np.stack([np.arange(16), np.full(16, 101),
                    np.full(16, 7)], 1).astype(np.int32)
    spokes = np.stack([np.arange(64) % 16, np.full(64, 102),
                       np.arange(64) // 16], 1).astype(np.int32)
    tr = np.concatenate([hub, spokes])
    store = build_store(tr, 1)
    # probed pattern (?y 102 ?z) has fan-out 4 per subject; shrink the
    # budget below it
    pats = [Pattern("?x", 101, 7), Pattern("?x", 102, "?z")]
    caps = Caps(scan_cap=4096, out_cap=1 << 14, probe_cap=2, row_cap=2)
    plan = compile_plan(store, pats, caps)
    join = [st for st in plan.steps if st.kind != "scan"]
    assert join and join[0].kind == "reduce_side"
    assert join[0].caps.probe_cap >= 4          # raised to the measured max
    want, ovars = execute_oracle(tr, pats)
    got = _rows(store, execute_local(store, plan), ovars)
    assert got == want
    assert int(np.asarray(execute_local(store, plan).overflow)) == 0


# ---------------------------------------------------------------------------
# quantize_cap (the one shared grid helper)
# ---------------------------------------------------------------------------


def test_quantize_cap_grid_boundaries():
    # floor of the grid
    assert quantize_cap(-3) == quantize_cap(0) == quantize_cap(8) == 8
    # exact grid points are fixed points
    for v in (8, 12, 16, 24, 32, 48, 64, 96, 128):
        assert quantize_cap(v) == v
    # one past a grid point lands on the next one
    assert quantize_cap(9) == 12
    assert quantize_cap(13) == 16
    assert quantize_cap(17) == 24
    assert quantize_cap(25) == 32
    assert quantize_cap(33) == 48
    assert quantize_cap(49) == 64
    # never undershoots, bounded overshoot (< 50%: consecutive grid
    # points are at most a 3/2 ratio apart)
    for v in range(1, 2000):
        q = quantize_cap(v)
        assert q >= v or v <= 8
        assert q <= max(v, 8) * 3 / 2


def test_logical_plan_input():
    tr = np.array([[1, 10, 2], [2, 11, 3]], np.int32)
    store = build_store(tr, 1)
    lp = LogicalPlan((Pattern("?x", 10, "?y"),))
    plan = compile_plan(store, lp, CAPS)
    assert plan.steps[0].kind == "scan"
    # relation_stats memoizes (second call hits the cache)
    s1 = relation_stats(store, Pattern("?x", 10, "?y"), ())
    s2 = relation_stats(store, Pattern("?x", 10, "?y"), ())
    assert s1 == s2 == (1, 1, 1)


def test_reduce_side_budget_covers_single_key_window():
    """The sort-merge windows on ONE join-key column (extra shared vars
    filter after the window), so the fallback budget must cover the max
    group per join-key VALUE — not the smaller max group over all bound
    positions (parallel-edge graphs expose the difference)."""
    rows = []
    for i in range(20):                     # hub x=0: 20 targets x 2 preds
        rows += [(0, 200, 100 + i), (0, 201, 100 + i)]
    for i in range(10):                     # background
        rows += [(1 + i, 200, 100 + i)]
    edges = [(0, 100, 100 + i) for i in range(20)] + \
            [(1 + i, 100, 100 + i) for i in range(10)]
    tr = np.array(edges + rows, np.int32)
    store = build_store(tr, 1)
    pats = [Pattern("?x", 100, "?y"), Pattern("?x", "?p", "?y")]
    caps = Caps(scan_cap=4096, out_cap=1 << 14, probe_cap=1, row_cap=1)
    plan = compile_plan(store, pats, caps)
    join = [st for st in plan.steps if st.kind != "scan"]
    assert join and join[0].kind == "reduce_side"
    # budget >= the hub's 40-row window on the join key (?x), not the
    # 2-row max group over the (x, y) pair
    assert join[0].caps.probe_cap >= 40
    want, ovars = execute_oracle(tr, pats)
    bnd = execute_local(store, plan)
    assert _rows(store, bnd, ovars) == want and len(want) > 0
    assert int(np.asarray(bnd.overflow)) == 0


def test_plan_mode_and_route_shards_are_not_silently_dropped(rng):
    """Executor args that a compiled plan would otherwise swallow: a
    'reduce' baseline request on a mapsin plan is an error; an explicit
    route_shards overrides the plan's baked-in measurement size."""
    import pytest
    tr = np.stack([rng.randint(0, 30, 300), rng.randint(100, 104, 300),
                   rng.randint(0, 30, 300)], 1).astype(np.int32)
    store = build_store(tr, 1)
    pats = [Pattern("?x", 101, "?y"), Pattern("?y", 102, "?z")]
    plan = compile_plan(store, pats, CAPS)          # route_shards=10
    with pytest.raises(ValueError):
        execute_local(store, plan, "reduce")
    stats: list = []
    execute_local(store, plan, stats=stats, route_shards=4)
    joins = [st for st in stats if st["kind"] != "scan"]
    assert joins and all(st["route_shards"] == 4 for st in joins)


def test_traffic_actual_prices_reduce_side_steps_as_reduce():
    """A hybrid plan's reduce_side step must be priced as a shuffle +
    full relation scan even under the mapsin comparison modes — zero
    probe bytes would flatter any plan containing one."""
    from repro.core.bgp import query_traffic_actual
    stats = [{"kind": "scan", "n_in": 0, "n_out": 10, "nv": 1,
              "relation": 10, "n_patterns": 1},
             {"kind": "reduce_side", "n_in": 10, "n_out": 40, "nv": 1,
              "relation": 50, "n_patterns": 1, "deliveries": 0,
              "route_shards": 4}]
    out = query_traffic_actual(stats, "mapsin_routed", 4, n_triples=1000)
    # shuffle Omega (10 rows x 8 B) + relation (50 x 16 B) + full scan
    assert out["network"] == 10 * (1 * 4 + 4) + 50 * 16
    assert out["scanned"] >= 1000 * 8
