"""Substrate tests: checkpoint roundtrip + crash/resume, AdamW vs numpy,
deterministic data pipeline."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest, load, save
from repro.configs import get_config, reduce_for_smoke
from repro.configs.base import SHAPES, ShapeConfig
from repro.data import batch_for_step, tokens_for
from repro.optim import OptConfig, adamw_update, cosine_lr, init_opt_state
from repro.runtime import SimulatedFailure, Trainer


def test_checkpoint_roundtrip():
    tree = {"a": {"b": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
            "c": [jnp.ones((4,), jnp.bfloat16), jnp.int32(7)]}
    with tempfile.TemporaryDirectory() as d:
        save(d, 42, {"params": tree})
        assert latest(d).endswith("step_00000042")
        step, out = load(latest(d), {"params": tree})
        assert step == 42
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out["params"])):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


def test_checkpoint_retention_and_atomicity():
    tree = {"x": jnp.zeros((2,))}
    with tempfile.TemporaryDirectory() as d:
        for s in range(6):
            save(d, s, {"params": tree}, keep=3)
        import os
        kept = sorted(x for x in os.listdir(d) if x.startswith("step_"))
        assert len(kept) == 3 and kept[-1] == "step_00000005"


def test_crash_resume_bit_exact():
    cfg = reduce_for_smoke(get_config("deepseek-7b"))
    shape = ShapeConfig("tiny", 32, 2, "train")
    opt = OptConfig(warmup_steps=2, decay_steps=20)
    with tempfile.TemporaryDirectory() as d1, tempfile.TemporaryDirectory() as d2:
        p1, _, m1 = Trainer(cfg, shape, d1, opt, ckpt_every=4).run(10)
        t2 = Trainer(cfg, shape, d2, opt, ckpt_every=4)
        with pytest.raises(SimulatedFailure):
            t2.run(10, fail_at=7)
        p2, _, m2 = Trainer(cfg, shape, d2, opt, ckpt_every=4).run(10)
        assert float(m1["loss"]) == float(m2["loss"])
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


def test_adamw_vs_numpy_reference():
    opt = OptConfig(learning_rate=1e-2, warmup_steps=0, decay_steps=10**9,
                    weight_decay=0.0, clip_norm=1e9, min_lr_ratio=1.0)
    p = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]], jnp.float32)}
    g = {"w": jnp.asarray([[0.1, 0.2], [-0.3, 0.4]], jnp.float32)}
    state = init_opt_state(p, opt)
    newp, state, _ = adamw_update(g, state, p, opt)
    # numpy adam, step 1
    gn = np.asarray(g["w"])
    mu = 0.1 * gn
    nu = 0.05 * gn ** 2
    mhat = mu / (1 - 0.9)
    nhat = nu / (1 - 0.95)
    want = np.asarray(p["w"]) - 1e-2 * mhat / (np.sqrt(nhat) + opt.eps)
    np.testing.assert_allclose(np.asarray(newp["w"]), want, rtol=1e-5)


def test_cosine_schedule():
    opt = OptConfig(learning_rate=1.0, warmup_steps=10, decay_steps=110,
                    min_lr_ratio=0.1)
    assert float(cosine_lr(opt, jnp.int32(0))) == 0.0
    assert abs(float(cosine_lr(opt, jnp.int32(10))) - 1.0) < 1e-6
    assert abs(float(cosine_lr(opt, jnp.int32(110))) - 0.1) < 1e-6
    mid = float(cosine_lr(opt, jnp.int32(60)))
    assert 0.1 < mid < 1.0


def test_data_determinism_and_alignment():
    cfg = get_config("yi-6b")
    shape = SHAPES["train_4k"]
    b1 = batch_for_step(cfg, shape, 7)
    b2 = batch_for_step(cfg, shape, 7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = batch_for_step(cfg, shape, 8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # next-token alignment
    t = tokens_for(0, 7, np.arange(shape.global_batch), shape.seq_len,
                   cfg.vocab_size)
    np.testing.assert_array_equal(b1["labels"], t[:, 1:])
    assert b1["tokens"].shape == (shape.global_batch, shape.seq_len)
    assert (b1["tokens"] >= 0).all() and (b1["tokens"] < cfg.vocab_size).all()


def test_data_row_slicing_matches_global():
    """DP hosts slicing rows must reproduce the global batch content."""
    cfg = get_config("yi-6b")
    shape = ShapeConfig("t", 128, 8, "train")
    full = batch_for_step(cfg, shape, 3)
    part = batch_for_step(cfg, shape, 3, rows=np.arange(4, 8))
    np.testing.assert_array_equal(full["tokens"][4:8], part["tokens"])
