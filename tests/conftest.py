import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.RandomState(0)


def make_lm_batch(cfg, b, s, rng):
    """Random batch matching an arch's input contract."""
    import jax.numpy as jnp
    if cfg.family == "vlm":
        st = s - cfg.num_patches
        return {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (b, st)), jnp.int32),
                "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (b, st)), jnp.int32),
                "patch_embeds": jnp.asarray(rng.randn(b, cfg.num_patches, 1024),
                                            jnp.float32)}
    if cfg.family == "audio":
        k = cfg.num_codebooks
        return {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (b, s, k)), jnp.int32),
                "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (b, s, k)), jnp.int32)}
    return {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (b, s)), jnp.int32),
            "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (b, s)), jnp.int32)}
