"""SPARQL BGP front-end: exact round-trip against the hand-built query
sets (text -> parse -> Pattern equality) + clean rejection of malformed
input (unknown prefix, undeclared term, non-BGP syntax)."""
import pytest

from repro.core.rdf import Dictionary
from repro.data.rdf_gen import (LUBM_SPARQL, SP2B_SPARQL, lubm_like,
                                sp2b_like)
from repro.serve import parse_bgp

_LUBM = lubm_like(1)
_SP2B = sp2b_like(200)


@pytest.mark.parametrize("qname", sorted(LUBM_SPARQL))
def test_lubm_text_roundtrips_to_patterns(qname):
    _, d, queries = _LUBM
    pq = parse_bgp(LUBM_SPARQL[qname], d)
    assert list(pq.patterns) == queries[qname]


@pytest.mark.parametrize("qname", sorted(SP2B_SPARQL))
def test_sp2b_text_roundtrips_to_patterns(qname):
    _, d, queries = _SP2B
    pq = parse_bgp(SP2B_SPARQL[qname], d)
    assert list(pq.patterns) == queries[qname]


def test_select_projection_and_star():
    _, d, _ = _LUBM
    pq = parse_bgp("SELECT ?y WHERE { ?x <takesCourse> ?y . }", d)
    assert pq.select == ("?y",) and pq.variables == ("?x", "?y")
    pq = parse_bgp("SELECT * WHERE { ?x <takesCourse> ?y . }", d)
    assert pq.select == ("?x", "?y")


def test_a_shorthand_is_rdf_type():
    _, d, queries = _LUBM
    pq = parse_bgp("SELECT ?x WHERE { ?x a <Student> . }", d)
    assert pq.patterns[0].p == d.lookup("rdf:type")


def test_literal_terms_resolve():
    _, d, _ = _SP2B
    pq = parse_bgp('SELECT ?a WHERE { ?a <dc:title> "title0" . }', d)
    assert pq.patterns[0].o == d.lookup("title0")


@pytest.mark.parametrize("text,needle", [
    # unknown prefix
    ("SELECT ?x WHERE { ?x ub:worksFor <Dept0.U0> . }", "unknown prefix"),
    # undeclared terms: IRI / literal / prefixed-name expansions
    ("SELECT ?x WHERE { ?x a <NoSuchClass> . }", "undeclared term"),
    ('SELECT ?x WHERE { ?x <name> "no-such-name" . }', "undeclared term"),
    ("PREFIX ub: <ub:>\nSELECT ?x WHERE { ?x ub:worksFor ?y . }",
     "undeclared term"),
    # non-BGP constructs, named in the error
    ("SELECT ?x WHERE { ?x a <Student> . FILTER(?x > 3) }", "FILTER"),
    ("SELECT ?x WHERE { OPTIONAL { ?x a <Student> . } }", "OPTIONAL"),
    ("SELECT ?x WHERE { ?x a <Student> . } LIMIT 5", "LIMIT"),
    ("ASK WHERE { ?x a <Student> . }", "ASK"),
    # malformed structure
    ("SELECT WHERE { ?x a <Student> . }", "SELECT"),
    ("SELECT ?x { ?x a <Student> . }", "WHERE"),
    ("SELECT ?x WHERE { ?x a <Student> .", "unterminated"),
    ("SELECT ?x WHERE { }", "empty basic graph pattern"),
    ("SELECT ?x WHERE { ?x a . }", "object"),
    ("SELECT ?z WHERE { ?x a <Student> . }", "does not occur"),
    ("SELECT ?x WHERE { ?x a <Student> ; <memberOf> ?y . }", ";"),
    ("PREFIX rdf <rdf:>\nSELECT ?x WHERE { ?x rdf:type <Student> . }",
     "PREFIX"),
])
def test_malformed_queries_raise_value_error(text, needle):
    _, d, _ = _LUBM
    with pytest.raises(ValueError, match="SPARQL"):
        try:
            parse_bgp(text, d)
        except ValueError as e:
            assert needle.lower() in str(e).lower(), (str(e), needle)
            raise


def test_parser_never_mints_dictionary_ids():
    _, d, _ = _LUBM
    n = len(d)
    with pytest.raises(ValueError):
        parse_bgp("SELECT ?x WHERE { ?x a <Imaginary> . }", d)
    parse_bgp("SELECT ?x WHERE { ?x a <Student> . }", d)
    assert len(d) == n
