"""Batched serving engine: per-slot results must be bit-identical to
execute_local (and the oracle); scheduler buckets by plan signature;
admission control + compile-cache bounding behave as configured; the
sharded (mesh) path is covered on a degenerate single-device mesh here
(fast tier) and on a forced 8-device mesh in test_multidevice.py."""
import dataclasses

import numpy as np
import pytest

from repro.core import (Caps, ExecConfig, Pattern, build_store,
                        execute_local, execute_oracle, rows_set)
from repro.data.rdf_gen import LUBM_SPARQL, lubm_like
from repro.serve import EngineBusy, ServeEngine, plan_signature

CAPS = Caps(scan_cap=4096, out_cap=4096, probe_cap=16, row_cap=64)


def random_graph(rng, n=300, subjects=40, preds=5, objects=40):
    return np.stack([rng.randint(0, subjects, n),
                     rng.randint(100, 100 + preds, n),
                     rng.randint(0, objects, n)], 1).astype(np.int32)


def _local_set(store, pats, vars_want):
    bnd = execute_local(store, pats, "mapsin", caps=CAPS)
    got = rows_set(bnd.table, bnd.valid, len(bnd.vars))
    if tuple(bnd.vars) != tuple(vars_want):
        perm = [bnd.vars.index(v) for v in vars_want]
        got = set(tuple(r[i] for i in perm) for r in got)
    return got


# ---------------------------------------------------------------------------
# plan signatures (the bucket key)
# ---------------------------------------------------------------------------


def test_same_shape_different_constants_share_signature(rng):
    store = build_store(random_graph(rng), 1)
    qa = [Pattern("?x", 101, 7), Pattern("?x", 102, "?y")]
    qb = [Pattern("?s", 101, 9), Pattern("?s", 102, "?t")]  # renamed + new const
    ta, ca, _ = plan_signature(store, qa, caps=CAPS)
    tb, cb, _ = plan_signature(store, qb, caps=CAPS)
    assert ta == tb
    assert ca.tolist() != cb.tolist()


def test_different_shapes_get_different_signatures(rng):
    store = build_store(random_graph(rng), 1)
    t1, _, _ = plan_signature(store, [Pattern("?x", 101, 7)], caps=CAPS)
    t2, _, _ = plan_signature(
        store, [Pattern("?x", 101, 7), Pattern("?x", 102, "?y")], caps=CAPS)
    assert t1 != t2


def test_repeated_constant_shares_a_slot(rng):
    store = build_store(random_graph(rng), 1)
    t, consts, _ = plan_signature(
        store, [Pattern(3, 101, "?x"), Pattern(3, 102, "?y")], caps=CAPS)
    # 4 constant occurrences, 3 distinct: the repeated subject shares a slot
    assert t.n_consts == 3 and sorted(consts.tolist()) == [3, 101, 102]


# ---------------------------------------------------------------------------
# batched execution == execute_local == oracle
# ---------------------------------------------------------------------------


def test_mixed_stream_matches_local_and_oracle(rng):
    tr = random_graph(rng, n=400)
    store = build_store(tr, 1)
    queries = []
    for const in (1, 5, 9, 13):                   # one template, 4 variants
        queries.append([Pattern("?x", 101, const), Pattern("?x", 102, "?y")])
    for const in (2, 7):                          # a second template
        queries.append([Pattern(const, 103, "?a"), Pattern("?a", 104, "?b")])
    queries.append([Pattern("?x", 100, "?y"), Pattern("?y", 101, "?z")])
    eng = ServeEngine(store, caps=CAPS, max_batch=8)
    results = eng.execute(queries)
    assert eng.dispatches == 3                    # one per template
    for pats, res in zip(queries, results):
        assert res.rows_set() == _local_set(store, pats, res.vars)
        want, ovars = execute_oracle(tr, pats)
        assert res.rows_set(ovars) == want
        assert res.overflow == 0


def test_multiway_star_template_batches(rng):
    tr = random_graph(rng, n=400)
    store = build_store(tr, 1)
    queries = [[Pattern("?x", 101, c), Pattern("?x", 102, "?a"),
                Pattern("?x", 103, "?b"), Pattern("?x", 104, "?c")]
               for c in (0, 3, 6, 11)]
    eng = ServeEngine(store, caps=CAPS)
    results = eng.execute(queries)
    assert eng.dispatches == 1
    for pats, res in zip(queries, results):
        assert res.rows_set() == _local_set(store, pats, res.vars)


def test_repeated_constant_multiway_group_executes(rng):
    """Two patterns sharing a constant subject must keep multiway's
    shared-prefix invariant through slot substitution."""
    tr = random_graph(rng, n=400)
    store = build_store(tr, 1)
    pats = [Pattern(3, 101, "?x"), Pattern(3, 102, "?y")]
    eng = ServeEngine(store, caps=CAPS)
    res = eng.execute([pats])[0]
    assert res.rows_set() == _local_set(store, pats, res.vars)
    want, ovars = execute_oracle(tr, pats)
    assert res.rows_set(ovars) == want


def test_lubm_sparql_stream_end_to_end():
    """Every LUBM query as SPARQL text through submit/drain; row sets
    equal the sequential engine's on identical (patterns, cfg)."""
    tr, d, qs = lubm_like(1)
    store = build_store(tr, 1)
    # probe_cap must hold Q8's memberOf fan-out (120 students/department):
    # below it the engine's mapsin-only template truncates while
    # execute_local's planner switches that step to the exact reduce_side
    # fallback — identical row sets need a non-truncating budget
    caps = Caps(scan_cap=1 << 15, out_cap=1 << 13, probe_cap=128,
                row_cap=64)
    eng = ServeEngine(store, d, caps=caps)
    names = sorted(LUBM_SPARQL)
    results = eng.execute([LUBM_SPARQL[n] for n in names])
    assert eng.dispatches < len(names)            # shapes actually shared
    for n, res in zip(names, results):
        bnd = execute_local(store, qs[n], "mapsin", caps=caps)
        want = rows_set(bnd.table, bnd.valid, len(bnd.vars))
        assert res.rows_set(bnd.vars) == want, n
        assert res.vars == tuple(bnd.vars), n
        assert len(want) > 0, n                   # queries are non-degenerate


def test_overflow_is_surfaced_per_slot(rng):
    tr = random_graph(rng, n=500)
    store = build_store(tr, 1)
    tiny = Caps(scan_cap=4096, out_cap=8, probe_cap=2, row_cap=4)
    pats = [Pattern("?x", 101, "?y"), Pattern("?y", 102, "?z")]
    # escalation off: this test checks the RAW surfaced counters (the
    # recovery machinery they feed is covered in test_robustness.py)
    eng = ServeEngine(store, caps=tiny, max_escalations=0)
    res = eng.execute([pats])[0]
    want, _ = execute_oracle(tr, pats)
    if len(want) > 8:
        assert res.overflow > 0
        # satellite: the per-step counters localize the drop to a step
        assert res.stats is not None
        assert sum(res.stats["overflow_per_step"]) == res.overflow
        assert len(res.stats["overflow_per_step"]) == len(res.stats["kinds"])


# ---------------------------------------------------------------------------
# scheduler: bucketing, admission control, compile cache
# ---------------------------------------------------------------------------


def test_admission_control_queue_depth(rng):
    store = build_store(random_graph(rng), 1)
    eng = ServeEngine(store, caps=CAPS, max_queue=4)
    pats = [Pattern("?x", 101, 7)]
    for _ in range(4):
        eng.submit(pats)
    with pytest.raises(EngineBusy):
        eng.submit(pats)
    eng.drain()
    eng.submit(pats)                              # queue drained: admitted


def test_per_bucket_max_batch(rng):
    store = build_store(random_graph(rng), 1)
    eng = ServeEngine(store, caps=CAPS, max_batch=4, max_queue=64)
    queries = [[Pattern("?x", 101, c % 13)] for c in range(10)]
    results = eng.execute(queries)
    assert eng.dispatches == 3                    # 4 + 4 + 2 slots
    assert eng.dispatched_queries == 10
    for pats, res in zip(queries, results):
        assert res.rows_set() == _local_set(store, pats, res.vars)


def test_fullest_bucket_dispatches_first(rng):
    store = build_store(random_graph(rng), 1)
    eng = ServeEngine(store, caps=CAPS, max_batch=8)
    a = [Pattern("?x", 101, 3)]                   # 1 request
    b = [Pattern("?x", 101, 5), Pattern("?x", 102, "?y")]  # 3 requests
    eng.submit(a)
    for c in (5, 7, 9):
        eng.submit([Pattern("?x", 101, c), Pattern("?x", 102, "?y")])
    first = eng.step()
    assert len(first) == 3                        # the fuller b-bucket
    assert len(eng.step()) == 1


def test_compile_cache_is_lru_bounded(rng):
    store = build_store(random_graph(rng), 1)
    eng = ServeEngine(store, caps=CAPS, compile_cache_size=2)
    shapes = [[Pattern("?x", 101, 1)],
              [Pattern("?x", 101, 2), Pattern("?x", 102, "?y")],
              [Pattern("?x", 100, "?y"), Pattern("?y", 103, "?z")]]
    for pats in shapes:
        eng.execute([pats])
    assert len(eng._compiled) <= 2
    res = eng.execute([shapes[0]])[0]             # evicted: recompiles, correct
    assert res.rows_set() == _local_set(store, shapes[0], res.vars)


def test_engine_rejects_reduce_mode_and_textless_dictionary(rng):
    store = build_store(random_graph(rng), 1)
    with pytest.raises(ValueError):
        ServeEngine(store, caps=CAPS, mode="reduce")
    eng = ServeEngine(store, caps=CAPS)             # no dictionary
    with pytest.raises(ValueError):
        eng.submit("SELECT ?x WHERE { ?x a <Student> . }")


def test_min_batch_defers_until_aged(rng):
    """min_batch/max_wait_s policy: sub-min_batch buckets defer, the aging
    override dispatches the oldest request's bucket past max_wait_s, and a
    bucket reaching min_batch dispatches immediately."""
    store = build_store(random_graph(rng), 1)
    eng = ServeEngine(store, caps=CAPS, max_batch=8, min_batch=4,
                      max_wait_s=5.0)
    for c in (1, 2):
        eng.submit([Pattern("?x", 101, c)], arrival=0.0)
    assert eng.step(now=1.0) == []                # below min_batch, young
    assert eng.pending() == 2
    aged = eng.step(now=6.0)                      # oldest aged past 5 s
    assert len(aged) == 2 and eng.pending() == 0
    for c in range(4):
        eng.submit([Pattern("?x", 101, c)], arrival=10.0)
    assert len(eng.step(now=10.0)) == 4           # min_batch met: no wait


def test_drain_forces_dispatch_below_min_batch(rng):
    store = build_store(random_graph(rng), 1)
    eng = ServeEngine(store, caps=CAPS, max_batch=8, min_batch=8,
                      max_wait_s=1e9)
    pats = [Pattern("?x", 101, 3)]
    eng.submit(pats, arrival=0.0)
    assert eng.step(now=0.0) == []                # policy defers...
    res = eng.drain()                             # ...drain overrides
    assert len(res) == 1
    assert res[0].rows_set() == _local_set(store, pats, res[0].vars)
    with pytest.raises(ValueError):               # malformed policy
        ServeEngine(store, caps=CAPS, max_batch=4, min_batch=8)


def test_compile_cache_key_includes_config(rng):
    """Toggling the engine's capacity budget must never reuse a compiled
    cascade built for the old caps (the key carries config AND caps)."""
    store = build_store(random_graph(rng), 1)
    eng = ServeEngine(store, caps=CAPS)
    pats = [Pattern("?x", 101, 7), Pattern("?x", 102, "?y")]
    eng.execute([pats])
    assert len(eng._compiled) == 1
    eng.caps = dataclasses.replace(CAPS,
                                   probe_cap=max(CAPS.probe_cap // 2, 2))
    res = eng.execute([pats])[0]
    assert len(eng._compiled) == 2                # distinct entry, no reuse
    assert res.rows_set() == _local_set(store, pats, res.vars)


def test_sharded_engine_degenerate_mesh_a2a(rng):
    """Single-device mesh, routing="a2a": the batched shard_map cascade
    (one all_to_all pair per step shared by the whole batch) on a 1-shard
    store must be row-identical to execute_local — the fast-tier cover
    for the forced-8-device test in test_multidevice.py."""
    import jax
    from jax.sharding import Mesh
    tr = random_graph(rng, n=400)
    store = build_store(tr, 1)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    cfg = ExecConfig(routing="a2a")
    eng = ServeEngine(store, cfg=cfg, caps=CAPS, mesh=mesh, max_batch=8)
    queries = [[Pattern("?x", 101, c), Pattern("?x", 102, "?y")]
               for c in (1, 5, 9, 13)]
    queries.append([Pattern("?x", 101, 3), Pattern("?x", 102, "?a"),
                    Pattern("?x", 103, "?b")])    # multiway star template
    results = eng.execute(queries)
    assert eng.dispatches == 2                    # two templates, one each
    for pats, res in zip(queries, results):
        assert res.rows_set() == _local_set(store, pats, res.vars)
        assert res.overflow == 0
    # mesh size must match the store's sharding
    with pytest.raises(ValueError):
        ServeEngine(build_store(tr, 2), cfg=cfg, caps=CAPS, mesh=mesh)


def test_minority_template_is_not_starved(rng):
    """Aging: a steady majority template must not starve a minority
    request past starvation_limit dispatches."""
    store = build_store(random_graph(rng), 1)
    eng = ServeEngine(store, caps=CAPS, max_batch=4, max_queue=256,
                      starvation_limit=2)
    minority = [Pattern("?x", 100, "?y"), Pattern("?y", 103, "?z")]
    rid_min = eng.submit(minority)
    served_at = None
    for i in range(12):
        # majority bucket refilled before every step: fullest-first alone
        # would pick it forever
        for c in range(5):
            eng.submit([Pattern("?x", 101, (i * 5 + c) % 13)])
        if any(r.request_id == rid_min for r in eng.step()):
            served_at = i
            break
    assert served_at is not None and served_at <= 2


def test_submit_accepts_physical_plan(rng):
    """API redesign: all three executors consume a PhysicalPlan — a
    pre-compiled plan goes straight into submit; plans with operators
    the template cascade cannot express are rejected at the front door."""
    from repro.core import Caps, compile_plan
    from repro.core.planner import ENGINE_OPERATORS
    tr = random_graph(rng, n=400)
    store = build_store(tr, 1)
    pats = [Pattern("?x", 101, 5), Pattern("?x", 102, "?y")]
    plan = compile_plan(store, pats, CAPS, operators=ENGINE_OPERATORS)
    eng = ServeEngine(store, caps=CAPS)
    res = eng.execute([plan])[0]
    assert res.rows_set() == _local_set(store, pats, res.vars)
    # a reduce_side plan cannot ride the seeded template cascade
    bad = compile_plan(store, [Pattern(3, "?p", "?o"),
                               Pattern("?x", "?p", "?y")],
                       Caps(probe_cap=2))
    if any(st.kind == "reduce_side" for st in bad.steps):
        with pytest.raises(ValueError):
            eng.submit(bad)
    # a plan compiled with a LARGER budget than the engine's would
    # silently truncate more than its own caps promise — front-door error
    big = compile_plan(store, pats,
                       dataclasses.replace(CAPS, out_cap=CAPS.out_cap * 2),
                       operators=ENGINE_OPERATORS)
    with pytest.raises(ValueError):
        eng.submit(big)
