"""Planner tests: variable-counting reorder, star grouping, traffic model."""
from repro.core import ExecConfig, Pattern, plan_steps, query_traffic
from repro.core.bgp import order_patterns


def test_variable_counting_order():
    pats = [Pattern("?x", 1, "?y"),        # 2 vars
            Pattern("?x", 1, 5),           # 1 var, bound o+p
            Pattern(3, 1, "?y"),           # 1 var, bound s+p (most selective)
            Pattern("?a", "?b", "?c")]     # 3 vars
    out = order_patterns(pats)
    assert out[0] == Pattern(3, 1, "?y")
    assert out[-1] == Pattern("?a", "?b", "?c")


def test_connected_patterns_preferred():
    pats = [Pattern("?x", 1, 5), Pattern("?z", 3, 7), Pattern("?x", 2, "?z")]
    out = order_patterns(pats)
    # second pattern must share a variable with the first (avoid cartesian)
    assert set(out[0].variables) & set(out[1].variables)


def test_multiway_grouping_star():
    pats = [Pattern("?x", 1, 2),
            Pattern("?x", 3, "?a"), Pattern("?x", 4, "?b"), Pattern("?x", 5, "?c")]
    steps = plan_steps(pats, ExecConfig(multiway=True))
    assert [s.kind for s in steps] == ["scan", "multiway"]
    assert len(steps[1].patterns) == 3
    steps = plan_steps(pats, ExecConfig(multiway=False))
    assert [s.kind for s in steps] == ["scan", "join", "join", "join"]


def test_multiway_not_grouped_across_dependency():
    # third pattern consumes ?a produced by the second -> cannot batch
    pats = [Pattern("?x", 1, 2), Pattern("?x", 3, "?a"), Pattern("?a", 4, "?b")]
    steps = plan_steps(pats, ExecConfig(multiway=True))
    assert [s.kind for s in steps] == ["scan", "join", "join"]


def test_traffic_model_mapsin_beats_reduce():
    """The paper's core claim, in the bytes model: MAPSIN ships keys+matches,
    reduce-side ships relations — for selective queries MAPSIN must win."""
    pats = [Pattern("?x", 1, 2), Pattern("?x", 3, "?a"), Pattern("?x", 4, "?b")]
    # selective query: small solution multiset vs large scanned relation
    cfg = ExecConfig(out_cap=1 << 8, probe_cap=4, bucket_cap=1 << 12)
    m = query_traffic(pats, "mapsin", cfg, num_shards=16)
    mr = query_traffic(pats, "mapsin_routed", cfg, num_shards=16)
    r = query_traffic(pats, "reduce", cfg, num_shards=16)
    assert mr < m < r
    # the routed protocol is shard-count-scalable: O(S*B), not O(S^2*B)
    m1k = query_traffic(pats, "mapsin_routed", cfg, num_shards=1024)
    assert m1k / query_traffic(pats, "mapsin_routed", cfg, num_shards=16) < 80
    # single shard: no network at all
    assert query_traffic(pats, "mapsin", cfg, num_shards=1) == 0


def test_multiway_saves_rounds():
    star = [Pattern("?x", 1, 2)] + [Pattern("?x", 10 + i, f"?v{i}") for i in range(4)]
    cfg_mw = ExecConfig(multiway=True, row_cap=8, probe_cap=8)
    cfg_2w = ExecConfig(multiway=False, row_cap=8, probe_cap=8)
    m_mw = query_traffic(star, "mapsin", cfg_mw, num_shards=16)
    m_2w = query_traffic(star, "mapsin", cfg_2w, num_shards=16)
    assert m_mw < m_2w  # one row-GET round vs n probe rounds
