"""Planner tests: variable-counting reorder, star grouping, traffic model."""
from repro.core import Caps, Pattern, build_store, compile_plan, query_traffic
from repro.core.bgp import order_patterns, plan_steps


def test_variable_counting_order():
    pats = [Pattern("?x", 1, "?y"),        # 2 vars
            Pattern("?x", 1, 5),           # 1 var, bound o+p
            Pattern(3, 1, "?y"),           # 1 var, bound s+p (most selective)
            Pattern("?a", "?b", "?c")]     # 3 vars
    out = order_patterns(pats)
    assert out[0] == Pattern(3, 1, "?y")
    assert out[-1] == Pattern("?a", "?b", "?c")


def test_connected_patterns_preferred():
    pats = [Pattern("?x", 1, 5), Pattern("?z", 3, 7), Pattern("?x", 2, "?z")]
    out = order_patterns(pats)
    # second pattern must share a variable with the first (avoid cartesian)
    assert set(out[0].variables) & set(out[1].variables)


def test_multiway_grouping_star():
    pats = [Pattern("?x", 1, 2),
            Pattern("?x", 3, "?a"), Pattern("?x", 4, "?b"), Pattern("?x", 5, "?c")]
    plan = compile_plan(None, pats, multiway=True)
    assert [s.kind for s in plan.steps] == ["scan", "multiway"]
    assert len(plan.steps[1].patterns) == 3
    plan = compile_plan(None, pats, multiway=False)
    assert [s.kind for s in plan.steps] == ["scan", "mapsin", "mapsin",
                                            "mapsin"]
    # deprecated shim still speaks the legacy kind vocabulary
    steps = plan_steps(pats, multiway=False)
    assert [s.kind for s in steps] == ["scan", "join", "join", "join"]


def test_multiway_not_grouped_across_dependency():
    # third pattern consumes ?a produced by the second -> cannot batch
    pats = [Pattern("?x", 1, 2), Pattern("?x", 3, "?a"), Pattern("?a", 4, "?b")]
    plan = compile_plan(None, pats, multiway=True)
    assert [s.kind for s in plan.steps] == ["scan", "mapsin", "mapsin"]


def test_traffic_model_mapsin_beats_reduce():
    """The paper's core claim, in the bytes model: MAPSIN ships keys+matches,
    reduce-side ships relations — for selective queries MAPSIN must win."""
    pats = [Pattern("?x", 1, 2), Pattern("?x", 3, "?a"), Pattern("?x", 4, "?b")]
    # selective query: small solution multiset vs large scanned relation
    caps = Caps(out_cap=1 << 8, probe_cap=4, bucket_cap=1 << 12)
    m = query_traffic(pats, "mapsin", caps, num_shards=16)
    mr = query_traffic(pats, "mapsin_routed", caps, num_shards=16)
    r = query_traffic(pats, "reduce", caps, num_shards=16)
    assert mr < m < r
    # the routed protocol is shard-count-scalable: O(S*B), not O(S^2*B)
    m1k = query_traffic(pats, "mapsin_routed", caps, num_shards=1024)
    assert m1k / query_traffic(pats, "mapsin_routed", caps, num_shards=16) < 80
    # single shard: no network at all
    assert query_traffic(pats, "mapsin", caps, num_shards=1) == 0


def test_multiway_saves_rounds():
    star = [Pattern("?x", 1, 2)] + [Pattern("?x", 10 + i, f"?v{i}") for i in range(4)]
    caps = Caps(row_cap=8, probe_cap=8)
    plan_mw = compile_plan(None, star, caps, multiway=True)
    plan_2w = compile_plan(None, star, caps, multiway=False)
    m_mw = query_traffic(plan_mw, "mapsin", caps, num_shards=16)
    m_2w = query_traffic(plan_2w, "mapsin", caps, num_shards=16)
    assert m_mw < m_2w  # one row-GET round vs n probe rounds


def test_cost_ordering_beats_heuristic_on_cardinality_trap():
    """A 1-var pattern with a HUGE relation vs a 2-var pattern with a tiny
    one: variable counting scans the big one first; the cost-based search
    must start from the cheap relation instead."""
    import numpy as np
    rng = np.random.RandomState(0)
    # pred 100: 1000 triples with o=7 (the trap: bound-o but unselective);
    # pred 101: 50 triples (?x 101 ?p)
    big = np.stack([rng.randint(0, 200, 1000), np.full(1000, 100),
                    np.full(1000, 7)], 1).astype(np.int32)
    small = np.stack([rng.randint(0, 200, 50), np.full(50, 101),
                      rng.randint(0, 40, 50)], 1).astype(np.int32)
    store = build_store(np.concatenate([big, small]), 1)
    pats = [Pattern("?x", 100, 7), Pattern("?x", 101, "?p")]
    heur = order_patterns(pats, store=store)
    assert heur[0] == pats[0]                   # variable counting: 1 var first
    plan = compile_plan(store, pats, ordering="cost")
    assert plan.steps[0].patterns[0] == pats[1]  # cost: small relation first
    assert plan.ordering == "cost"
