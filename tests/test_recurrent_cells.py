"""Recurrent cell equivalences: chunkwise/parallel forms == step recurrences."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.recurrent import rg_lru_scan
from repro.models.xlstm import (mlstm_chunkwise, mlstm_decode, slstm_seq,
                                slstm_step)


def test_rg_lru_scan_vs_sequential(rng):
    b, s, w = 2, 37, 8
    u = jnp.asarray(rng.randn(b, s, w), jnp.float32)
    log_a = jnp.asarray(-np.abs(rng.randn(b, s, w)), jnp.float32)
    got = rg_lru_scan(u, log_a, None)
    h = np.zeros((b, w), np.float32)
    a = np.exp(np.asarray(log_a))
    un = np.asarray(u)
    for t in range(s):
        h = a[:, t] * h + un[:, t]
        np.testing.assert_allclose(np.asarray(got[:, t]), h, rtol=2e-5, atol=1e-5)


def test_mlstm_chunkwise_vs_decode(rng):
    """Chunkwise-parallel mLSTM must equal the token-by-token recurrence."""
    b, s, h, e = 2, 50, 2, 8
    q = jnp.asarray(rng.randn(b, s, h, e), jnp.float32)
    k = jnp.asarray(rng.randn(b, s, h, e), jnp.float32)
    v = jnp.asarray(rng.randn(b, s, h, e), jnp.float32)
    log_i = jnp.asarray(rng.randn(b, s, h) * 0.5, jnp.float32)
    log_f = jnp.asarray(-np.abs(rng.randn(b, s, h)) * 0.1, jnp.float32)
    out_chunk, state_chunk = mlstm_chunkwise(q, k, v, log_i, log_f, chunk=16)
    # sequential reference
    C = jnp.zeros((b, h, e, e))
    n = jnp.zeros((b, h, e))
    m = jnp.full((b, h), -1e30)
    outs = []
    st = (C, n, m)
    for t in range(s):
        o, st = mlstm_decode(q[:, t], k[:, t], v[:, t], log_i[:, t],
                             log_f[:, t], st)
        outs.append(o)
    want = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(out_chunk), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    # states agree too (stabilized representation: compare C*exp(m))
    for a_, b_ in [(state_chunk[0] * jnp.exp(state_chunk[2])[..., None, None],
                    st[0] * jnp.exp(st[2])[..., None, None]),
                   (state_chunk[1] * jnp.exp(state_chunk[2])[..., None],
                    st[1] * jnp.exp(st[2])[..., None])]:
        np.testing.assert_allclose(np.asarray(a_), np.asarray(b_),
                                   rtol=2e-3, atol=2e-3)


def test_mlstm_chunk_size_invariance(rng):
    b, s, h, e = 1, 64, 2, 4
    args = [jnp.asarray(rng.randn(b, s, h, e), jnp.float32) for _ in range(3)]
    gates = [jnp.asarray(rng.randn(b, s, h) * 0.3, jnp.float32),
             jnp.asarray(-np.abs(rng.randn(b, s, h)) * 0.2, jnp.float32)]
    o1, _ = mlstm_chunkwise(*args, *gates, chunk=8)
    o2, _ = mlstm_chunkwise(*args, *gates, chunk=64)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=2e-4,
                               atol=2e-4)


def test_slstm_seq_vs_step(rng):
    b, s, h, e = 2, 20, 2, 4
    gates = jnp.asarray(rng.randn(b, s, 4, h, e) * 0.5, jnp.float32)
    p = {"R": jnp.asarray(rng.randn(4, h, e, e) * 0.1, jnp.float32)}
    hs, state = slstm_seq(gates, p)
    z = jnp.zeros((b, h, e))
    st = (z, z, z, jnp.full((b, h, e), -1e30))
    for t in range(s):
        hn, cn, nn, mn = slstm_step(gates[:, t], *st, p)
        st = (hn, cn, nn, mn)
        np.testing.assert_allclose(np.asarray(hs[:, t]), np.asarray(hn),
                                   rtol=2e-5, atol=2e-5)
