"""End-to-end behaviour: the paper's workload on the paper's benchmarks.

Runs the LUBM-like and SP2B-like generators, executes every benchmark query
through BOTH engines (MAPSIN + reduce-side baseline) and checks exact
agreement with the brute-force oracle, plus the paper's headline claims in
the traffic model (keys+matches << full relations; multiway saves rounds).
"""
import numpy as np
import pytest

from repro.core import (Caps, build_store, compile_plan, execute_local,
                        execute_oracle, query_traffic, rows_set)
from repro.data import lubm_like, sp2b_like

pytestmark = pytest.mark.slow  # minutes: every query x both engines x oracle

# probe_cap must cover the fattest GET (a department's ~120 members)
CAPS = Caps(scan_cap=1 << 15, out_cap=1 << 15, probe_cap=256, row_cap=64)


def _check_query(tr, pats, mode):
    store = build_store(tr, 1)
    want, ovars = execute_oracle(tr, pats)
    bnd = execute_local(store, pats, mode=mode, caps=CAPS)
    got = rows_set(bnd.table, bnd.valid, len(bnd.vars))
    if tuple(bnd.vars) != ovars:
        perm = [bnd.vars.index(v) for v in ovars]
        got = set(tuple(r[i] for i in perm) for r in got)
    assert int(bnd.overflow) == 0
    assert got == want, f"{len(got)} vs {len(want)}"
    return len(want)


@pytest.fixture(scope="module")
def lubm():
    return lubm_like(1, seed=0)


@pytest.fixture(scope="module")
def sp2b():
    return sp2b_like(400, seed=0)


@pytest.mark.parametrize("qname", ["Q1", "Q3", "Q4", "Q5", "Q6", "Q7", "Q8",
                                   "Q11", "Q13", "Q14"])
@pytest.mark.parametrize("mode", ["mapsin", "reduce"])
def test_lubm_queries(lubm, qname, mode):
    tr, d, queries = lubm
    n = _check_query(tr, queries[qname], mode)
    if qname in ("Q6", "Q14"):
        assert n > 100  # broad class scans are non-trivial


@pytest.mark.parametrize("qname", ["Q1", "Q2", "Q3a", "Q10"])
@pytest.mark.parametrize("mode", ["mapsin", "reduce"])
def test_sp2b_queries(sp2b, qname, mode):
    tr, d, queries = sp2b
    _check_query(tr, queries[qname], mode)


def test_paper_claim_traffic(lubm):
    """MAPSIN data movement << reduce-side for the selective LUBM queries —
    measured from ACTUAL row counts. 'total' = interconnect + storage reads
    (reduce-side re-scans the whole dataset per pattern: HDFS has no index —
    the effect the paper's selective-query speedups come from)."""
    from repro.core.bgp import query_traffic_actual
    tr, _, queries = lubm
    store = build_store(tr, 1)
    for qname, min_ratio in (("Q1", 20), ("Q4", 20), ("Q5", 5), ("Q8", 2)):
        stats: list = []
        execute_local(store, queries[qname], "mapsin", caps=CAPS, stats=stats)
        m = query_traffic_actual(stats, "mapsin_routed", 10, store.n_triples)
        r = query_traffic_actual(stats, "reduce", 10, store.n_triples)
        ratio = r["total"] / m["total"]
        assert ratio > min_ratio, f"{qname}: ratio {ratio:.1f} < {min_ratio}"


def test_paper_claim_multiway(lubm):
    """Q4-style star: multiway executes in ONE round and matches cascade."""
    tr, _, queries = lubm
    store = build_store(tr, 1)
    q4 = queries["Q4"]
    a = execute_local(store, compile_plan(store, q4, CAPS, multiway=True))
    b = execute_local(store, compile_plan(store, q4, CAPS, multiway=False))
    ra = rows_set(a.table, a.valid, len(a.vars))
    rb = rows_set(b.table, b.valid, len(b.vars))
    if a.vars != b.vars:
        perm = [a.vars.index(v) for v in b.vars]
        ra = set(tuple(r[i] for i in perm) for r in ra)
    assert ra == rb and len(ra) > 0
    plan = compile_plan(store, q4, CAPS, multiway=True)
    assert sum(1 for s in plan.steps if s.kind == "multiway") >= 1
