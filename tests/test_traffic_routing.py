"""query_traffic_actual's measured-deliveries branches, routed-vs-broadcast
byte accounting, and the a2a probe-dispatch bucketing machinery.

The routed numbers in BENCH_distributed.json are only trustworthy if
query_traffic_actual uses the MEASURED probe->region fan-out when the
stats were recorded for the same cluster size — and falls back to the
broadcast-equivalent n_in (never silently under-reports) otherwise.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (Caps, ExecConfig, Pattern, build_store,
                        compile_plan, execute_local, execute_oracle,
                        execute_sharded)
from repro.core.bgp import query_traffic_actual, rows_set
from repro.core.distributed import auto_bucket_cap, bucket_rows

# probe record bytes (bgp.py): routed records are lo/hi + origin (the
# residual filters stay on the origin shard, PR 4); broadcast all_gathers
# the filters too. MATCH: returned match bytes.
REC_ROUTED, REC_BCAST, MATCH = 20, 44, 12


def _stats(deliveries=12, route_shards=4, n_in=10, n_out=5):
    st = {"kind": "join", "n_in": n_in, "n_out": n_out, "nv": 1,
          "relation": 8, "n_patterns": 1}
    if route_shards is not None:
        st.update(deliveries=deliveries, route_shards=route_shards)
    return [{"kind": "scan", "n_in": 0, "n_out": n_in, "nv": 1,
             "relation": n_in, "n_patterns": 1}, st]


def test_routed_uses_measured_deliveries_when_shards_match():
    out = query_traffic_actual(_stats(deliveries=12, route_shards=4),
                               "mapsin_routed", 4, n_triples=100)
    assert out["probe_bytes_routed"] == 12 * REC_ROUTED
    assert out["network"] == 12 * REC_ROUTED + 5 * MATCH


def test_routed_falls_back_to_n_in_on_shard_mismatch():
    out = query_traffic_actual(_stats(deliveries=12, route_shards=4),
                               "mapsin_routed", 8, n_triples=100)
    # measured fan-out was for a 4-region layout; for 8 shards it
    # substitutes n_in (broadcast-equivalent, one delivery per probe)
    assert out["probe_bytes_routed"] == 10 * REC_ROUTED
    assert out["network"] == 10 * REC_ROUTED + 5 * MATCH


def test_routed_falls_back_when_deliveries_missing():
    out = query_traffic_actual(_stats(route_shards=None),
                               "mapsin_routed", 4, n_triples=100)
    assert out["probe_bytes_routed"] == 10 * REC_ROUTED


def test_broadcast_bytes_scale_with_cluster_size():
    for s in (2, 4, 10):
        out = query_traffic_actual(_stats(route_shards=4), "mapsin", s,
                                   n_triples=100)
        assert out["probe_bytes_broadcast"] == 10 * REC_BCAST * (s - 1)
        assert out["network"] == 10 * REC_BCAST * (s - 1) + 5 * MATCH
    # routed probe bytes are reported alongside regardless of mode
    out = query_traffic_actual(_stats(route_shards=4), "mapsin", 4, 100)
    assert out["probe_bytes_routed"] == 12 * REC_ROUTED


def test_measured_stats_feed_routed_accounting():
    """End-to-end: instrumented run records deliveries for route_shards;
    matching/mismatching cluster sizes hit the two branches."""
    rng = np.random.RandomState(0)
    tr = np.stack([rng.randint(0, 40, 400), rng.randint(100, 104, 400),
                   rng.randint(0, 40, 400)], 1).astype(np.int32)
    store = build_store(tr, 1)
    pats = [Pattern("?x", 101, "?y"), Pattern("?y", 102, "?z")]
    stats: list = []
    execute_local(store, pats, "mapsin", stats=stats, route_shards=3)
    joins = [st for st in stats if st["kind"] != "scan"]
    assert joins and all(st["route_shards"] == 3 for st in joins)
    measured = query_traffic_actual(stats, "mapsin_routed", 3,
                                    store.n_triples)
    fallback = query_traffic_actual(stats, "mapsin_routed", 5,
                                    store.n_triples)
    want_measured = sum(st["deliveries"] * REC_ROUTED * st["n_patterns"]
                        for st in joins)
    want_fallback = sum(st["n_in"] * REC_ROUTED * st["n_patterns"] for st in joins)
    assert measured["probe_bytes_routed"] == want_measured
    assert fallback["probe_bytes_routed"] == want_fallback
    # broadcast pays (S-1)x on every probe record
    want_bcast = sum(st["n_in"] * REC_BCAST * st["n_patterns"] for st in joins)
    assert measured["probe_bytes_broadcast"] == want_bcast * 2


# ---------------------------------------------------------------------------
# a2a dispatch machinery (single-device: bucketing + end-to-end plumbing)
# ---------------------------------------------------------------------------


def test_bucket_rows_packs_and_drops():
    send = jnp.asarray([[1, 0], [1, 1], [0, 1], [1, 0], [1, 0]], bool)
    vals = jnp.asarray([10, 20, 30, 40, 50], jnp.int64)
    (buf,), slot, dropped = bucket_rows(send, 2, [vals])
    np.testing.assert_array_equal(np.asarray(buf), [[10, 20], [20, 30]])
    # records 3 and 4 spilled dest-0's bucket (cap 2)
    np.testing.assert_array_equal(np.asarray(dropped), [0, 0, 0, 1, 1])
    slot = np.asarray(slot)
    assert slot[0, 0] == 0 and slot[1, 0] == 1 and slot[1, 1] == 0
    assert slot[3, 0] == 2 and slot[0, 1] == 2  # cap == spilled / unaddressed


def test_bucket_rows_multi_payload_2d():
    send = jnp.asarray([[0, 1], [1, 1]], bool)
    a = jnp.asarray([1, 2], jnp.int32)
    b = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int64)
    (ba, bb), _, dropped = bucket_rows(send, 2, [a, b])
    np.testing.assert_array_equal(np.asarray(ba), [[2, 0], [1, 2]])
    np.testing.assert_array_equal(np.asarray(bb),
                                  [[[4, 5, 6], [0, 0, 0]],
                                   [[1, 2, 3], [4, 5, 6]]])
    assert int(dropped.sum()) == 0


def test_auto_bucket_cap_bounds():
    assert auto_bucket_cap(4096, 8) == 1024      # 2x uniform share
    assert auto_bucket_cap(64, 8) == 32          # floor
    assert auto_bucket_cap(16, 8) == 16          # never beyond the batch
    assert auto_bucket_cap(100, 1) == 100


@pytest.mark.parametrize("routing", ["broadcast", "a2a"])
def test_sharded_routing_single_device(routing):
    """Both routings execute (and agree with the oracle) on a 1-device mesh
    — fast-tier coverage of the full a2a code path without forcing devices."""
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    rng = np.random.RandomState(1)
    tr = np.stack([rng.randint(0, 30, 300), rng.randint(100, 104, 300),
                   rng.randint(0, 30, 300)], 1).astype(np.int32)
    store = build_store(tr, num_shards=1)
    pats = [Pattern("?x", 101, "?y"), Pattern("?y", 102, "?z")]
    cfg = ExecConfig(routing=routing)
    t, v, ovf, vars_ = execute_sharded(store, pats, mesh, "mapsin", cfg,
                                       caps=Caps(out_cap=4096,
                                                 probe_cap=128))
    got = rows_set(t, v, len(vars_))
    want, ovars = execute_oracle(tr, pats)
    perm = [vars_.index(x) for x in ovars]
    assert {tuple(r[i] for i in perm) for r in got} == want
    assert int(np.asarray(ovf).sum()) == 0


def test_sharded_a2a_matches_broadcast_2dev():
    """CHEAP multi-shard a2a equivalence for the fast tier: 2 forced host
    devices in a subprocess (the flag must not leak into this process),
    tiny caps — covers cross-shard bucket claiming and shard-order offset
    composition, which are degenerate no-ops on a 1-device mesh. The full
    8-shard fat-row version lives in test_multidevice.py (slow tier)."""
    import os
    import subprocess
    import sys
    import textwrap
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=2",
               JAX_PLATFORMS="cpu",   # the flag only forces the HOST platform
               PYTHONPATH=src)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent("""
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.core import (Pattern, build_store, execute_sharded,
                                execute_oracle, rows_set, ExecConfig)
        mesh = Mesh(np.array(jax.devices()).reshape(2), ("data",))
        rng = np.random.RandomState(5)
        tr = np.stack([rng.randint(0, 20, 200), rng.randint(100, 103, 200),
                       rng.randint(0, 20, 200)], 1).astype(np.int32)
        store = build_store(tr, num_shards=2)
        pats = [Pattern("?x", 101, "?y"), Pattern("?y", 102, "?z")]
        want, ovars = execute_oracle(tr, pats)
        got = {}
        for routing in ("broadcast", "a2a"):
            from repro.core import Caps
            cfg = ExecConfig(routing=routing)
            t, v, ovf, vars_ = execute_sharded(store, pats, mesh, "mapsin",
                                               cfg,
                                               caps=Caps(out_cap=1024,
                                                         probe_cap=64))
            perm = [vars_.index(x) for x in ovars]
            got[routing] = {tuple(r[i] for i in perm)
                            for r in rows_set(t, v, len(vars_))}
            assert int(np.asarray(ovf).sum()) == 0
        assert got["a2a"] == got["broadcast"] == want, (
            len(got["a2a"]), len(got["broadcast"]), len(want))
        print("OK", len(want))
    """)], env=env, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    assert out.stdout.startswith("OK")


def test_dist_probe_rejects_unknown_routing():
    from repro.core.distributed import dist_probe
    z = jnp.zeros((4,), jnp.int64)
    with pytest.raises(ValueError):
        dist_probe(z, z, jnp.zeros((4, 3), jnp.int64), (False,) * 3, (),
                   jnp.zeros((8,), jnp.int64), 4, "data", routing="bogus")
    with pytest.raises(ValueError):
        dist_probe(z, z, jnp.zeros((4, 3), jnp.int64), (False,) * 3, (),
                   jnp.zeros((8,), jnp.int64), 4, "data", routing="a2a",
                   splits=None)


# ---------------------------------------------------------------------------
# measured a2a capacity EMBEDDING (planner.embed_a2a_caps — the planner
# subsumed tune_a2a_bucket_cap / tuned_step_answer_caps / _maybe_tune)
# ---------------------------------------------------------------------------


def _join_caps(plan):
    return [st.caps for st in plan.steps[1:]
            if st.kind in ("mapsin", "multiway")]


def test_embedded_a2a_caps_use_measured_max_region_load():
    rng = np.random.RandomState(0)
    tr = np.stack([rng.randint(0, 40, 400), rng.randint(100, 104, 400),
                   rng.randint(0, 40, 400)], 1).astype(np.int32)
    store = build_store(tr, 1)
    pats = [Pattern("?x", 101, "?y"), Pattern("?y", 102, "?z")]
    caps = Caps(out_cap=4096, probe_cap=64)
    plan = compile_plan(store, pats, caps, routing="a2a", num_shards=4)
    jc = _join_caps(plan)
    assert jc, "plan must have a2a-capable join steps"
    stats: list = []
    execute_local(store, pats, "mapsin", caps=caps, stats=stats,
                  route_shards=4)
    want = max(st["deliveries_max_region"] for st in stats
               if st["kind"] != "scan")
    assert all(c.a2a_bucket_cap == max(want, 8) for c in jc)
    assert all(c.a2a_bucket_cap <= caps.out_cap for c in jc)
    # selective query: measured cap beats the static 2x-uniform share
    assert jc[0].a2a_bucket_cap < auto_bucket_cap(caps.out_cap, 4)
    # the answer leg is right-sized to the measured max range length,
    # never looser than the configured probe cap
    measured_len = max(st["probe_len_max"] for st in stats
                       if st["kind"] != "scan")
    assert all(c.probe_cap <= caps.probe_cap for c in jc)
    assert all(c.probe_cap >= min(measured_len, caps.probe_cap) for c in jc)
    # cached: recompiling returns the identical embedded plan
    plan2 = compile_plan(store, pats, caps, routing="a2a", num_shards=4)
    assert plan2 == plan
    assert any(k[0] == "a2a_embed" for k in store.plan_cache)


def test_sharded_a2a_auto_embeds_and_stays_exact():
    """execute_sharded with caps.a2a_bucket_cap=0 must embed measured caps
    (plan-cache entry appears) and still match the oracle exactly."""
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    rng = np.random.RandomState(2)
    tr = np.stack([rng.randint(0, 30, 300), rng.randint(100, 104, 300),
                   rng.randint(0, 30, 300)], 1).astype(np.int32)
    store = build_store(tr, num_shards=1)
    pats = [Pattern("?x", 101, "?y"), Pattern("?y", 102, "?z")]
    cfg = ExecConfig(routing="a2a")
    t, v, ovf, vars_ = execute_sharded(store, pats, mesh, "mapsin", cfg,
                                       caps=Caps(out_cap=4096,
                                                 probe_cap=128))
    assert any(k[0] == "a2a_embed" for k in store.plan_cache)
    got = rows_set(t, v, len(vars_))
    want, ovars = execute_oracle(tr, pats)
    perm = [vars_.index(x) for x in ovars]
    assert {tuple(r[i] for i in perm) for r in got} == want
    assert int(np.asarray(ovf).sum()) == 0


def test_embedded_a2a_caps_overflow_falls_back_to_out_cap():
    """A truncated measurement run sees a truncated probe set; the sharded
    run keeps out_cap rows PER SHARD, so the embedding must not trust it."""
    rng = np.random.RandomState(3)
    tr = np.stack([rng.randint(0, 30, 600), rng.randint(100, 102, 600),
                   rng.randint(0, 30, 600)], 1).astype(np.int32)
    store = build_store(tr, 1)
    pats = [Pattern("?x", 100, "?y"), Pattern("?y", 101, "?z")]
    tiny = Caps(out_cap=16, probe_cap=2)         # guaranteed truncation
    plan = compile_plan(store, pats, tiny, routing="a2a", num_shards=4,
                        operators=("scan", "mapsin", "multiway"))
    jc = _join_caps(plan)
    assert jc and all(c.a2a_bucket_cap == 16 for c in jc)
    # overflowed measurement: answer caps stay at the configured budget
    assert all(c.probe_cap == tiny.probe_cap for c in jc)


def test_precompiled_plan_embed_uses_plan_budget():
    """A pre-compiled plan arriving at execute_sharded without embedded
    a2a caps must size its drop-free bucket fallback from the plan's OWN
    out_cap, not from an unrelated default budget."""
    from jax.sharding import Mesh
    from repro.core.planner import embed_a2a_caps
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    rng = np.random.RandomState(4)
    tr = np.stack([rng.randint(0, 30, 300), rng.randint(100, 104, 300),
                   rng.randint(0, 30, 300)], 1).astype(np.int32)
    store = build_store(tr, 1)
    pats = [Pattern("?x", 101, "?y"), Pattern("?y", 102, "?z")]
    big = Caps(out_cap=1 << 16, probe_cap=128)   # > the default Caps budget
    plan = compile_plan(store, pats, big)        # no num_shards: unembedded
    assert all(st.caps.a2a_bucket_cap == 0 for st in plan.steps)
    # caps=None: the bound comes off the plan's steps (here out_cap 2^16)
    emb = embed_a2a_caps(store, plan, None, 4)
    jc = [st.caps for st in emb.steps[1:] if st.kind in ("mapsin",
                                                         "multiway")]
    assert jc and all(0 < c.a2a_bucket_cap <= big.out_cap for c in jc)
    # end to end through execute_sharded with a pre-compiled plan
    t, v, ovf, vars_ = execute_sharded(store, plan, mesh, "mapsin",
                                       ExecConfig(routing="a2a"))
    want, ovars = execute_oracle(tr, pats)
    perm = [vars_.index(x) for x in ovars]
    got = {tuple(r[i] for i in perm) for r in rows_set(t, v, len(vars_))}
    assert got == want and int(np.asarray(ovf).sum()) == 0
