"""Fused Pallas probe_gather (interpret) vs the jnp reference probe path.

The acceptance contract for kernels/probe_gather.py: identical match keys
(at valid slots), identical validity masks, identical per-probe missed
counts — on random stores and patterns, including empty ranges, residual
filters, intra-pattern variable repeats, fat rows, and overflow."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import ExecConfig, Pattern, build_store, execute_local, \
    execute_oracle, rows_set
from repro.core.mapsin import apply_residual, gather_range, probe
from repro.core.plan import make_plan
from repro.core.rdf import pack3
from repro.kernels import ops


def _jnp_reference(keys, lo, hi, flt, msk, eq, cap):
    k, valid, missed = gather_range(keys, lo, hi, cap)
    valid = apply_residual(k, valid, flt, msk, eq)
    return np.where(np.asarray(valid), np.asarray(k), 0), \
        np.asarray(valid), np.asarray(missed)


def _fused(keys, lo, hi, flt, msk, eq, cap, block_k=256, block_q=32):
    k, valid, missed = ops.probe_gather(keys, lo, hi, flt, cap=cap,
                                        flt_mask=msk, eq_positions=eq,
                                        interpret=True, block_k=block_k,
                                        block_q=block_q)
    return np.asarray(k), np.asarray(valid), np.asarray(missed)


def _check(keys, lo, hi, flt, msk, eq, cap, **kw):
    kr, vr, mr = _jnp_reference(keys, lo, hi, flt, msk, eq, cap)
    kg, vg, mg = _fused(keys, lo, hi, flt, msk, eq, cap, **kw)
    np.testing.assert_array_equal(vr, vg, err_msg="validity mask")
    np.testing.assert_array_equal(kr, kg, err_msg="match keys")
    np.testing.assert_array_equal(mr, mg, err_msg="missed counts")


@pytest.mark.parametrize("seed", range(5))
def test_random_equivalence(seed):
    """Random sorted stores x random probe ranges x random residuals."""
    rng = np.random.RandomState(seed)
    m = rng.randint(50, 4000)
    b = rng.randint(1, 200)
    cap = int(rng.choice([1, 2, 8, 16]))
    keys = jnp.asarray(np.sort(pack3(rng.randint(0, 40, m),
                                     rng.randint(0, 6, m),
                                     rng.randint(0, 40, m))))
    v = rng.randint(0, 45, b).astype(np.int64)       # some miss entirely
    z = np.zeros(b, np.int64)
    lo = pack3(v, z, z)
    hi = pack3(v + 1, z, z)
    # a slice of probes with a (v, p) two-component prefix
    p2 = rng.randint(0, 6, b).astype(np.int64)
    two = rng.rand(b) < 0.3
    lo = np.where(two, pack3(v, p2, z), lo)
    hi = np.where(two, pack3(v, p2 + 1, z), hi)
    # some invalid/empty probes, as the executor emits for invalid rows
    empty = rng.rand(b) < 0.2
    lo, hi = np.where(empty, 0, lo), np.where(empty, 0, hi)
    flt = np.zeros((b, 3), np.int64)
    flt[:, 2] = rng.randint(0, 40, b)
    msk = (False, False, bool(seed % 2))             # residual on/off
    _check(keys, jnp.asarray(lo), jnp.asarray(hi), jnp.asarray(flt), msk,
           (), cap)


def test_fat_row_overflow():
    """One fat subject owning >> cap triples: missed must count the spill."""
    n = 500
    s = np.zeros(n, np.int64)                        # all triples on subject 0
    p = np.arange(n, dtype=np.int64) % 3
    o = np.arange(n, dtype=np.int64) % 170
    keys = jnp.asarray(np.sort(pack3(s, p, o)))
    z = np.zeros(4, np.int64)
    lo = jnp.asarray(pack3(np.zeros(4, np.int64), z, z))
    hi = jnp.asarray(pack3(np.ones(4, np.int64), z, z))
    flt = jnp.asarray(np.zeros((4, 3), np.int64))
    cap = 8
    kr, vr, mr = _jnp_reference(keys, lo, hi, flt, (False,) * 3, (), cap)
    kg, vg, mg = _fused(keys, lo, hi, flt, (False,) * 3, (), cap)
    np.testing.assert_array_equal(vr, vg)
    np.testing.assert_array_equal(kr, kg)
    np.testing.assert_array_equal(mr, mg)
    assert mg.min() > 0                              # the spill IS surfaced


def test_empty_and_degenerate_ranges():
    keys = jnp.asarray(np.sort(pack3(
        np.array([1, 1, 2, 5], np.int64), np.array([0, 1, 0, 2], np.int64),
        np.array([3, 4, 5, 6], np.int64))))
    z = np.zeros(3, np.int64)
    lo = jnp.asarray(np.array([0, pack3(np.int64(3), 0, 0),
                               pack3(np.int64(9), 0, 0)], np.int64))
    hi = jnp.asarray(np.array([0, pack3(np.int64(4), 0, 0),
                               pack3(np.int64(10), 0, 0)], np.int64))
    flt = jnp.asarray(np.zeros((3, 3), np.int64))
    _check(keys, lo, hi, flt, (False,) * 3, (), 4)


def test_eq_positions_self_join():
    """Intra-pattern repeated variable (?x p ?x) as an eq-position filter."""
    rng = np.random.RandomState(7)
    m = 600
    keys = jnp.asarray(np.sort(pack3(rng.randint(0, 12, m),
                                     rng.randint(0, 4, m),
                                     rng.randint(0, 12, m))))
    b = 30
    v = rng.randint(0, 12, b).astype(np.int64)
    z = np.zeros(b, np.int64)
    lo = jnp.asarray(pack3(v, z, z))
    hi = jnp.asarray(pack3(v + 1, z, z))
    flt = jnp.asarray(np.zeros((b, 3), np.int64))
    _check(keys, lo, hi, flt, (False,) * 3, ((0, 2),), 8)


@pytest.mark.parametrize("block_k,block_q", [(64, 16), (512, 128)])
def test_block_shape_sweep(block_k, block_q):
    rng = np.random.RandomState(3)
    m, b, cap = 1500, 70, 4
    keys = jnp.asarray(np.sort(pack3(rng.randint(0, 30, m),
                                     rng.randint(0, 5, m),
                                     rng.randint(0, 30, m))))
    v = rng.randint(0, 30, b).astype(np.int64)
    z = np.zeros(b, np.int64)
    flt = np.zeros((b, 3), np.int64)
    flt[:, 1] = rng.randint(0, 5, b)
    _check(keys, jnp.asarray(pack3(v, z, z)), jnp.asarray(pack3(v + 1, z, z)),
           jnp.asarray(flt), (False, True, False), (), cap,
           block_k=block_k, block_q=block_q)


def test_probe_dispatch_matches_jnp():
    """core/mapsin.probe(impl='pallas_interpret') == probe(impl='jnp') on a
    real plan (prefix + residual filter from a cascading pattern)."""
    rng = np.random.RandomState(11)
    tr = np.stack([rng.randint(0, 25, 400), rng.randint(100, 104, 400),
                   rng.randint(0, 25, 400)], 1).astype(np.int32)
    store = build_store(tr, 1)
    keys = store.flat_keys(0)
    plan = make_plan(Pattern("?x", 101, "?y"), ("?x",))
    table = jnp.asarray(rng.randint(0, 25, (40, 1)), jnp.int32)
    valid = jnp.asarray(rng.rand(40) < 0.8)
    k_ref, v_ref, m_ref = probe(plan, keys, table, valid, 8, impl="jnp")
    k_got, v_got, m_got = probe(plan, keys, table, valid, 8,
                                impl="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(v_ref), np.asarray(v_got))
    np.testing.assert_array_equal(
        np.where(np.asarray(v_ref), np.asarray(k_ref), 0), np.asarray(k_got))
    np.testing.assert_array_equal(np.asarray(m_ref), np.asarray(m_got))


def test_full_engine_pallas_interpret_vs_oracle():
    """End-to-end: the jitted cascade with the fused kernel == oracle."""
    rng = np.random.RandomState(5)
    tr = np.stack([rng.randint(0, 20, 250), rng.randint(100, 103, 250),
                   rng.randint(0, 20, 250)], 1).astype(np.int32)
    store = build_store(tr, 1)
    pats = [Pattern("?x", 101, "?y"), Pattern("?y", 102, "?z")]
    from repro.core import Caps, compile_plan
    caps = Caps(scan_cap=2048, out_cap=4096, probe_cap=32)
    cfg = ExecConfig(impl="pallas_interpret")
    want, ovars = execute_oracle(tr, pats)
    plan = compile_plan(store, pats, caps, multiway=False)
    bnd = execute_local(store, plan, cfg=cfg)
    got = rows_set(bnd.table, bnd.valid, len(bnd.vars))
    if tuple(bnd.vars) != ovars:
        perm = [bnd.vars.index(v) for v in ovars]
        got = set(tuple(r[i] for i in perm) for r in got)
    assert int(bnd.overflow) == 0
    assert got == want
