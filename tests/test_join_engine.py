"""MAPSIN join engine vs brute-force oracle — fixed queries + property tests."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip; the suite still runs
    from _hypothesis_stub import given, settings, st

from repro.core import (Caps, Pattern, build_store, compile_plan,
                        execute_local, execute_oracle, rows_set)

CAPS = Caps(scan_cap=4096, out_cap=8192, probe_cap=16, row_cap=64)


def random_graph(rng, n=300, subjects=40, preds=5, objects=40):
    return np.stack([rng.randint(0, subjects, n),
                     rng.randint(100, 100 + preds, n),
                     rng.randint(0, objects, n)], 1).astype(np.int32)


QUERIES = {
    "chain2": [Pattern("?x", 101, "?y"), Pattern("?y", 102, "?z")],
    "chain3": [Pattern("?x", 100, "?y"), Pattern("?y", 101, "?z"),
               Pattern("?z", 102, "?w")],
    "star3": [Pattern("?x", 101, "?a"), Pattern("?x", 102, "?b"),
              Pattern("?x", 103, "?c")],
    "const_o": [Pattern("?x", 101, 7), Pattern("?x", 102, "?y")],
    "const_s": [Pattern(3, 101, "?x"), Pattern("?x", 104, "?y")],
    "cycle": [Pattern("?x", 100, "?y"), Pattern("?y", 101, "?x")],
    "self_loop": [Pattern("?x", 100, "?x")],
    "pred_var": [Pattern("?s", "?p", 5)],
    "obj_star": [Pattern("?a", 100, "?o"), Pattern("?b", 101, "?o")],
}


def check(tr, pats, mode, multiway, caps=CAPS):
    store = build_store(tr, num_shards=1)
    want, ovars = execute_oracle(tr, pats)
    plan = compile_plan(store, pats, caps, mode=mode, multiway=multiway)
    bnd = execute_local(store, plan)
    got = rows_set(bnd.table, bnd.valid, len(bnd.vars))
    if tuple(bnd.vars) != ovars:
        perm = [bnd.vars.index(v) for v in ovars]
        got = set(tuple(r[i] for i in perm) for r in got)
    assert int(bnd.overflow) == 0, f"overflow {int(bnd.overflow)}"
    assert got == want, f"{len(got)} != {len(want)}"


@pytest.mark.parametrize("mode", ["mapsin", "reduce"])
@pytest.mark.parametrize("multiway", [True, False])
@pytest.mark.parametrize("qname", sorted(QUERIES))
def test_fixed_queries(mode, multiway, qname, rng):
    check(random_graph(rng), QUERIES[qname], mode, multiway)


def test_skewed_fat_rows(rng):
    """rdf:type fat-row scenario: one object owns half the triples."""
    tr = random_graph(rng, n=200)
    fat = np.stack([np.arange(200) % 60, np.full(200, 104),
                    np.zeros(200)], 1).astype(np.int32)
    tr = np.concatenate([tr, fat])
    pats = [Pattern("?x", 104, 0), Pattern("?x", 100, "?y")]
    for mode in ("mapsin", "reduce"):
        check(tr, pats, mode, True)


def test_overflow_is_surfaced(rng):
    tr = random_graph(rng, n=500)
    caps = Caps(scan_cap=4096, out_cap=8, probe_cap=2, row_cap=4)
    store = build_store(tr, 1)
    bnd = execute_local(store, QUERIES["chain2"], "mapsin", caps=caps)
    want, _ = execute_oracle(tr, QUERIES["chain2"])
    if len(want) > 8:
        assert int(bnd.overflow) > 0  # drops are counted, never silent


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       n=st.integers(20, 400),
       qname=st.sampled_from(sorted(QUERIES)),
       mode=st.sampled_from(["mapsin", "reduce"]),
       multiway=st.booleans())
def test_property_random_graphs(seed, n, qname, mode, multiway):
    """Invariant: engine(query, G) == oracle(query, G) for random G."""
    rng = np.random.RandomState(seed)
    tr = random_graph(rng, n=n, subjects=max(n // 10, 5), preds=5,
                      objects=max(n // 10, 5))
    check(tr, QUERIES[qname], mode, multiway)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_property_multiway_equals_cascade(seed):
    """Alg. 3 (single row-GET) must equal the 2-way cascade (Alg. 1)."""
    rng = np.random.RandomState(seed)
    tr = random_graph(rng)
    store = build_store(tr, 1)
    pats = QUERIES["star3"]
    a = execute_local(store, compile_plan(store, pats, CAPS, multiway=True))
    b = execute_local(store, compile_plan(store, pats, CAPS, multiway=False))
    ra = rows_set(a.table, a.valid, len(a.vars))
    rb = rows_set(b.table, b.valid, len(b.vars))
    if a.vars != b.vars:
        perm = [a.vars.index(v) for v in b.vars]
        ra = set(tuple(r[i] for i in perm) for r in ra)
    assert ra == rb
