"""Unit tests: key packing, compaction, plan selection (paper Table 3)."""
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip; the suite still runs
    from _hypothesis_stub import given, settings, st

import jax.numpy as jnp

from repro.core.mapsin import compact
from repro.core.plan import make_plan
from repro.core.rdf import MAX_ID, Pattern, pack3, unpack3
from repro.core.triple_store import OPS, SPO, build_store


@settings(max_examples=50, deadline=None)
@given(st.integers(0, MAX_ID), st.integers(0, MAX_ID), st.integers(0, MAX_ID))
def test_pack_unpack_roundtrip(a, b, c):
    k = pack3(np.int64(a), np.int64(b), np.int64(c))
    s, p, o = unpack3(k)
    assert (int(s), int(p), int(o)) == (a, b, c)
    assert int(k) >= 0  # 63-bit, sortable as signed int64


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 100), st.integers(0, 10),
                          st.integers(0, 100)), min_size=2, max_size=50))
def test_pack_preserves_lexicographic_order(triples):
    arr = np.array(triples, np.int64)
    keys = pack3(arr[:, 0], arr[:, 1], arr[:, 2])
    order_keys = np.argsort(keys, kind="stable")
    order_lex = np.lexsort((arr[:, 2], arr[:, 1], arr[:, 0]))
    assert np.array_equal(np.sort(keys[order_keys]), np.sort(keys[order_lex]))
    np.testing.assert_array_equal(arr[order_keys], arr[order_lex])


def test_compact_basic():
    rows = jnp.arange(20, dtype=jnp.int32).reshape(10, 2)
    valid = jnp.asarray([1, 0, 1, 0, 1, 1, 0, 0, 1, 1], bool)
    out, mask, dropped = compact(rows, valid, 4)
    assert int(dropped) == 2  # 6 valid, cap 4
    got = np.asarray(out)[np.asarray(mask)]
    want = np.asarray(rows)[np.asarray(valid)][:4]
    np.testing.assert_array_equal(got, want)


# ---- paper Table 3: pattern -> index/prefix selection ----

def test_plan_table3():
    cases = [
        (Pattern(1, 2, 3), SPO, 3),      # (s,p,o): full GET
        (Pattern("?s", 2, 3), OPS, 2),   # (?s,p,o): T_ops prefix (o,p)
        (Pattern(1, "?p", 3), SPO, 1),   # (s,?p,o): prefix s, filter o
        (Pattern(1, 2, "?o"), SPO, 2),   # (s,p,?o): prefix (s,p)
        (Pattern("?s", "?p", 3), OPS, 1),
        (Pattern("?s", 2, "?o"), SPO, 0),  # SCAN + predicate filter
        (Pattern(1, "?p", "?o"), SPO, 1),
        (Pattern("?s", "?p", "?o"), SPO, 0),
    ]
    for pat, idx, plen in cases:
        plan = make_plan(pat, ())
        assert plan.index == idx, pat
        assert len(plan.prefix) == plen, pat
    # bound-by-binding variables count as bound (cascading case)
    plan = make_plan(Pattern("?x", 2, "?o"), ("?x",))
    assert plan.index == SPO and len(plan.prefix) == 2


def test_store_sharding_balanced():
    rng = np.random.RandomState(0)
    tr = np.stack([rng.randint(0, 50, 1000), rng.randint(0, 5, 1000),
                   rng.randint(0, 50, 1000)], 1).astype(np.int32)
    # skew: a single fat object row
    fat = np.stack([np.arange(500), np.full(500, 2), np.zeros(500)], 1).astype(np.int32)
    store = build_store(np.concatenate([tr, fat]), num_shards=8)
    counts = np.asarray(store.counts_ops)
    # equal-count splits: every shard full except possibly the last — the
    # fat row spans shards instead of overloading one (the rdf:type fix)
    assert (counts[:-1] == counts.max()).all() and counts[-1] <= counts.max()
    # keys are globally sorted across shards
    flat = np.asarray(store.keys_ops).reshape(-1)
    valid = flat[flat < np.iinfo(np.int64).max]
    assert (np.diff(valid) >= 0).all()


# ---- LRU plan cache (bounded under many-tenant query streams) ----

def test_lru_cache_evicts_cold_keeps_hot():
    from repro.core.triple_store import LRUCache
    c = LRUCache(maxsize=3)
    c["a"], c["b"], c["c"] = 1, 2, 3
    assert c["a"] == 1            # refresh "a" -> "b" is now coldest
    c["d"] = 4
    assert "b" not in c and set(c) == {"a", "c", "d"}
    assert c.get("b", "gone") == "gone"
    c["e"], c["f"] = 5, 6
    assert len(c) == 3            # never exceeds maxsize


def test_plan_cache_eviction_keeps_hot_entries_compiled():
    """Churning the plan cache with cold entries must not evict the
    compiled cascade of a query that keeps executing (the hot tenant)."""
    from repro.core import Caps, execute_local
    from repro.core.triple_store import LRUCache
    rng = np.random.RandomState(0)
    tr = np.stack([rng.randint(0, 20, 200), rng.randint(100, 103, 200),
                   rng.randint(0, 20, 200)], 1).astype(np.int32)
    store = build_store(tr, 1)
    store.plan_cache = LRUCache(maxsize=16)
    caps = Caps(out_cap=1024, probe_cap=16)
    pats = [Pattern("?x", 101, "?y"), Pattern("?y", 102, "?z")]
    execute_local(store, pats, "mapsin", caps=caps)
    ck = [k for k in store.plan_cache if k[0] == "cascade"]
    assert len(ck) == 1
    jitted_before = store.plan_cache[ck[0]]
    # churn: way more cold inserts than maxsize, touching the hot query
    # every few inserts (as a live tenant would)
    for i in range(100):
        store.plan_cache[("cold", i)] = i
        if i % 4 == 0:
            execute_local(store, pats, "mapsin", caps=caps)
    assert ck[0] in store.plan_cache
    assert store.plan_cache[ck[0]] is jitted_before  # never recompiled
    assert ("cold", 0) not in store.plan_cache       # cold entries evicted
    assert len(store.plan_cache) <= 16
