"""Fallback shims when `hypothesis` is not installed (see requirements-dev.txt).

The tier-1 suite must collect and run without optional dev dependencies:
property tests decorated with the stub `given` are individually skipped,
while every example-based test in the same module still executes.  Import
pattern used by the test modules:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:          # property tests skip; the suite still runs
        from _hypothesis_stub import given, settings, st
"""
import pytest


def given(*_args, **_kwargs):
    def deco(fn):
        return pytest.mark.skip(reason="hypothesis not installed")(fn)
    return deco


def settings(*_args, **_kwargs):
    return lambda fn: fn


class _Strategies:
    """Any strategy constructor (st.integers(...), st.lists(...)) -> None;
    the stub `given` never calls them."""

    def __getattr__(self, _name):
        return lambda *a, **k: None


st = _Strategies()
