"""Robustness layer (DESIGN.md §7): overflow-escalation retries along the
quantize_cap grid with the exact reduce_side fallback, per-query deadlines
(queued / mid-dispatch / during-escalation), graceful degradation
(priority shedding, EngineBusy payload, bounded-inexact mode), and the
seeded fault-injection harness with answer-leg checksum detection.

The fast tier covers the a2a fault hooks on a degenerate 1-device mesh
(the collective + checksum code paths are identical at any shard count);
test_multidevice.py runs the 8-device chaos case."""
import numpy as np
import pytest

from repro.core import (Caps, ExecConfig, Pattern, build_store,
                        execute_local, execute_oracle, rows_set)
from repro.core.planner import escalate_caps, next_cap, quantize_cap
from repro.serve import (EngineBusy, Fault, FaultPlan, QueryShed,
                         QueryTimeout, ServeEngine)

CAPS = Caps(scan_cap=4096, out_cap=4096, probe_cap=16, row_cap=64)
TINY = Caps(scan_cap=4096, out_cap=8, probe_cap=2, row_cap=4)
CHAIN = [Pattern("?x", 101, "?y"), Pattern("?y", 102, "?z")]


def random_graph(rng, n=500, subjects=40, preds=5, objects=40):
    return np.stack([rng.randint(0, subjects, n),
                     rng.randint(100, 100 + preds, n),
                     rng.randint(0, objects, n)], 1).astype(np.int32)


def _mesh1():
    import jax
    from jax.sharding import Mesh
    return Mesh(np.array(jax.devices()[:1]), ("data",))


# ---------------------------------------------------------------------------
# cap escalation on the quantize_cap grid
# ---------------------------------------------------------------------------


def test_next_cap_lands_on_grid_from_both_families():
    # from 3*2^(k-1) grid points the successor is 2^(k+1), never 2^k
    assert next_cap(12) == 16 and next_cap(24) == 32 and next_cap(48) == 64
    # from powers of two: the next power of two
    assert next_cap(8) == 16 and next_cap(16) == 32 and next_cap(1024) == 2048
    # floor of the grid
    assert next_cap(1) == 8 and next_cap(0) == 8


def test_escalation_chain_never_repeats_and_stays_on_grid():
    caps = TINY
    seen = set()
    for _ in range(12):
        caps = escalate_caps(caps)
        for dim in ("scan_cap", "probe_cap", "row_cap", "out_cap"):
            v = getattr(caps, dim)
            assert quantize_cap(v) == v            # on the quantize grid
            assert (dim, v) not in seen            # strictly increasing
            seen.add((dim, v))
        assert caps.a2a_bucket_cap == 0            # re-embedded per budget


def test_escalation_is_geometric():
    c1 = escalate_caps(TINY)
    assert (c1.out_cap, c1.probe_cap, c1.row_cap, c1.scan_cap) == (
        16, 8, 8, 8192)
    c2 = escalate_caps(c1)
    assert (c2.out_cap, c2.probe_cap, c2.row_cap) == (32, 16, 16)


# ---------------------------------------------------------------------------
# overflow-escalation retries: exactness under undersized caps
# ---------------------------------------------------------------------------


def test_heavy_hitter_escalation_matches_oracle(rng):
    """The acceptance case: deliberately undersized caps, yet the engine
    returns row sets bit-identical to the execute_local oracle — no
    silent truncation survives escalation."""
    tr = random_graph(rng)
    store = build_store(tr, 1)
    want, ovars = execute_oracle(tr, CHAIN)
    assert len(want) > TINY.out_cap                # genuinely heavy
    eng = ServeEngine(store, caps=TINY)
    res = eng.execute([CHAIN])[0]
    assert res.rows_set(ovars) == want
    assert res.overflow == 0
    assert eng.escalations + eng.fallbacks > 0     # it actually escalated
    bnd = execute_local(store, CHAIN, "mapsin", caps=CAPS)
    assert res.rows_set(bnd.vars) == rows_set(bnd.table, bnd.valid,
                                              len(bnd.vars))


def test_attempt_bound_terminates_at_reduce_side_fallback(rng):
    """max_escalations=1: the very first overflow goes straight to the
    unrestricted planner's exact fallback — within the attempt bound."""
    tr = random_graph(rng)
    store = build_store(tr, 1)
    want, ovars = execute_oracle(tr, CHAIN)
    eng = ServeEngine(store, caps=TINY, max_escalations=1)
    res = eng.execute([CHAIN])[0]
    assert res.stats["fallback"] == "reduce_side"
    assert res.stats["attempt"] == 0               # no batched retries
    assert res.rows_set(ovars) == want and res.overflow == 0
    assert eng.fallbacks == 1 and eng.escalations == 0


def test_escalations_bounded_then_exact(rng):
    tr = random_graph(rng)
    store = build_store(tr, 1)
    eng = ServeEngine(store, caps=TINY, max_escalations=3)
    res = eng.execute([CHAIN])[0]
    assert eng.escalations <= 2                    # attempts 1..max-1
    assert res.overflow == 0


def test_escalated_templates_reuse_compile_cache(rng):
    """A second identical heavy query re-walks the escalation ladder but
    compiles nothing new: escalated plans ride the same LRU caches."""
    tr = random_graph(rng)
    store = build_store(tr, 1)
    eng = ServeEngine(store, caps=TINY)
    eng.execute([CHAIN])
    compiled = len(eng._compiled)
    d0 = eng.dispatches
    eng.execute([CHAIN])
    assert len(eng._compiled) == compiled          # all cache hits
    assert eng.dispatches > d0                     # but it did re-dispatch


def test_escalation_off_preserves_truncating_behavior(rng):
    tr = random_graph(rng)
    store = build_store(tr, 1)
    want, _ = execute_oracle(tr, CHAIN)
    eng = ServeEngine(store, caps=TINY, max_escalations=0)
    res = eng.execute([CHAIN])[0]
    assert res.overflow > 0 and len(res.rows) < len(want)
    assert sum(res.stats["overflow_per_step"]) == res.overflow


def test_bounded_inexact_mode_serves_capped_with_counters(rng):
    """inexact_ok: explicit opt-in serves the capped result with the
    overflow counters attached (stats['degraded']) instead of escalating
    or shedding."""
    tr = random_graph(rng)
    store = build_store(tr, 1)
    eng = ServeEngine(store, caps=TINY)
    rid = eng.submit(CHAIN, inexact_ok=True)
    res = eng.drain()
    assert len(res) == 1 and res[0].request_id == rid
    assert res[0].overflow > 0
    assert res[0].stats["degraded"] is True
    assert sum(res[0].stats["overflow_per_step"]) == res[0].overflow
    assert eng.escalations == 0 and eng.fallbacks == 0


# ---------------------------------------------------------------------------
# deadlines: queued / mid-dispatch / during escalation
# ---------------------------------------------------------------------------


def test_deadline_expired_while_queued(rng):
    store = build_store(random_graph(rng), 1)
    eng = ServeEngine(store, caps=CAPS)
    rid = eng.submit(CHAIN, arrival=0.0, deadline_s=0.5)
    out = eng.step(now=1.0)
    assert len(out) == 1 and isinstance(out[0], QueryTimeout)
    t = out[0]
    assert t.request_id == rid and t.phase == "queued"
    assert t.rows.shape[0] == 0                    # shed, never truncated
    assert t.deadline_s == 0.5 and t.waited_s == pytest.approx(1.0)
    assert eng.pending() == 0 and eng.dispatches == 0


def test_deadline_expired_mid_dispatch(rng):
    """A delay fault stalls the dispatch past the deadline: the finished
    batch's rows are DISCARDED for that query — a QueryTimeout with the
    attempt's partial stats, never a late result delivered as complete."""
    store = build_store(random_graph(rng), 1)
    fp = FaultPlan((Fault(0, 0, "delay", epoch=0, delay_s=10.0),),
                   period=1 << 20)
    eng = ServeEngine(store, cfg=ExecConfig(routing="a2a"), caps=CAPS,
                      mesh=_mesh1(), fault_plan=fp)
    rid = eng.submit(CHAIN, arrival=0.0, deadline_s=5.0)
    out = eng.step(now=0.0)
    assert len(out) == 1 and isinstance(out[0], QueryTimeout)
    t = out[0]
    assert t.request_id == rid and t.phase == "dispatch"
    assert t.rows.shape[0] == 0
    assert "overflow_per_step" in t.stats          # the attempt's counters
    assert eng.dispatches == 1                     # it DID run


def test_deadline_expired_during_escalation_retry(rng):
    tr = random_graph(rng)
    store = build_store(tr, 1)
    eng = ServeEngine(store, caps=TINY)
    rid = eng.submit(CHAIN, arrival=0.0, deadline_s=1e6)
    out = eng.step(now=0.0)                        # overflows -> re-enqueued
    assert out == [] and eng.pending() == 1
    assert eng.escalations == 1
    out = eng.step(now=2e6)                        # expires before retry
    assert len(out) == 1 and isinstance(out[0], QueryTimeout)
    t = out[0]
    assert t.request_id == rid and t.phase == "escalation"
    assert t.rows.shape[0] == 0
    # partial-stats payload: the last completed attempt's counters
    assert t.stats is not None and sum(t.stats["overflow_per_step"]) > 0
    assert t.stats["attempt"] == 0


def test_dispatch_watchdog(rng):
    store = build_store(random_graph(rng), 1)
    fp = FaultPlan((Fault(0, 0, "delay", epoch=0, delay_s=60.0),),
                   period=1 << 20)
    eng = ServeEngine(store, cfg=ExecConfig(routing="a2a"), caps=CAPS,
                      mesh=_mesh1(), fault_plan=fp, dispatch_timeout_s=5.0)
    eng.submit(CHAIN)
    out = eng.step()
    assert len(out) == 1 and isinstance(out[0], QueryTimeout)
    assert out[0].phase == "dispatch"


# ---------------------------------------------------------------------------
# graceful degradation: EngineBusy payload + priority shedding
# ---------------------------------------------------------------------------


def test_engine_busy_returns_plan_and_retry_after(rng):
    store = build_store(random_graph(rng), 1)
    eng = ServeEngine(store, caps=CAPS, max_queue=2)
    eng.execute([CHAIN])                           # time one dispatch
    assert eng._service_ewma > 0.0
    eng.submit([Pattern("?x", 101, 7)])
    eng.submit([Pattern("?x", 101, 8)])
    with pytest.raises(EngineBusy) as ei:
        eng.submit(CHAIN)
    busy = ei.value
    assert busy.plan is not None                   # planning work returned
    assert tuple(busy.plan.patterns) == tuple(CHAIN)
    assert busy.retry_after > 0.0                  # measured-service hint
    # the returned plan resubmits directly (skips replanning) once drained
    eng.drain()
    rid = eng.submit(busy.plan)
    assert [r.request_id for r in eng.drain()] == [rid]


def test_priority_shedding_with_tenant_accounting(rng):
    store = build_store(random_graph(rng), 1)
    eng = ServeEngine(store, caps=CAPS, max_queue=2)
    ra = eng.submit([Pattern("?x", 101, 7)], tenant="bulk", priority=0)
    rb = eng.submit([Pattern("?x", 101, 8)], tenant="bulk", priority=0)
    rc = eng.submit([Pattern("?x", 101, 9)], tenant="paid", priority=5)
    res = eng.drain()
    shed = [r for r in res if isinstance(r, QueryShed)]
    # the lowest-priority, most recently enqueued request was evicted
    assert len(shed) == 1 and shed[0].request_id == rb
    assert shed[0].retry_after >= 0.0
    assert eng.shed_by_tenant == {"bulk": 1}
    # every submit got exactly one result; the high-priority one has rows
    assert {r.request_id for r in res} == {ra, rb, rc}
    served = {r.request_id for r in res if not isinstance(r, QueryShed)}
    assert served == {ra, rc}


def test_equal_priority_still_raises_busy(rng):
    store = build_store(random_graph(rng), 1)
    eng = ServeEngine(store, caps=CAPS, max_queue=1)
    eng.submit([Pattern("?x", 101, 7)], priority=3)
    with pytest.raises(EngineBusy):
        eng.submit([Pattern("?x", 101, 8)], priority=3)


# ---------------------------------------------------------------------------
# fault injection + answer-leg checksums (fast tier: 1-device a2a mesh)
# ---------------------------------------------------------------------------


def test_fault_plan_is_deterministic_and_hashable():
    a = FaultPlan.sample(7, num_shards=8, n_steps=2, rate=0.05, horizon=32)
    b = FaultPlan.sample(7, num_shards=8, n_steps=2, rate=0.05, horizon=32)
    assert a == b and hash(a) == hash(b)
    assert a != FaultPlan.sample(8, num_shards=8, n_steps=2, rate=0.05,
                                 horizon=32)
    n_legs = 32 * 2 * 8
    assert 0 < len(a.faults) < 0.2 * n_legs        # ~5% of legs
    sel = a.selection(3, 2)
    assert len(sel) == 2 and all(len(s) == 2 for s in sel)
    # period wraps: epoch k and k+horizon see the same faults
    assert a.at(3, 0) == a.at(3 + 32, 0)


def test_bad_fault_kind_rejected():
    with pytest.raises(ValueError):
        Fault(0, 0, "meteor")


def test_fault_plan_requires_a2a_mesh(rng):
    store = build_store(random_graph(rng), 1)
    fp = FaultPlan((Fault(0, 0, "drop"),))
    with pytest.raises(ValueError):
        ServeEngine(store, caps=CAPS, fault_plan=fp)   # no mesh
    with pytest.raises(ValueError):
        ServeEngine(store, caps=CAPS, mesh=_mesh1(), fault_plan=fp)


def test_drop_and_corrupt_detected_retried_rows_identical(rng):
    """The chaos invariant on the fast tier: one dropped and one
    corrupted answer leg are detected by the checksums, the dispatch is
    retried onto a clean epoch, and the delivered rows are identical to
    execute_local — zero wrong rows."""
    tr = random_graph(rng)
    store = build_store(tr, 1)
    bnd = execute_local(store, CHAIN, "mapsin", caps=CAPS)
    want = rows_set(bnd.table, bnd.valid, len(bnd.vars))
    assert len(want) > 0
    fp = FaultPlan((Fault(0, 0, "drop", epoch=0),
                    Fault(0, 0, "corrupt", epoch=1)))
    eng = ServeEngine(store, cfg=ExecConfig(routing="a2a"), caps=CAPS,
                      mesh=_mesh1(), fault_plan=fp)
    res = eng.execute([CHAIN])[0]
    assert res.rows_set(bnd.vars) == want
    assert eng.corrupt_detected >= 2               # both bad legs seen
    assert eng.fault_redispatches == 2             # retried past both
    assert "fault_unrecovered" not in (res.stats or {})


def test_checked_clean_path_identical_and_unretried(rng):
    tr = random_graph(rng)
    store = build_store(tr, 1)
    bnd = execute_local(store, CHAIN, "mapsin", caps=CAPS)
    want = rows_set(bnd.table, bnd.valid, len(bnd.vars))
    eng = ServeEngine(store, cfg=ExecConfig(routing="a2a"), caps=CAPS,
                      mesh=_mesh1(), check_answers=True)
    res = eng.execute([CHAIN])[0]
    assert res.rows_set(bnd.vars) == want
    assert eng.fault_redispatches == 0 and eng.corrupt_detected == 0


def test_unrecovered_fault_never_returns_wrong_rows(rng):
    """Faults on EVERY epoch exhaust the retry budget: the result is
    flagged fault_unrecovered and its surviving rows are a SUBSET of the
    truth (quarantined blocks zeroed) — wrong rows are impossible."""
    tr = random_graph(rng)
    store = build_store(tr, 1)
    bnd = execute_local(store, CHAIN, "mapsin", caps=CAPS)
    want = rows_set(bnd.table, bnd.valid, len(bnd.vars))
    fp = FaultPlan(tuple(Fault(0, 0, "corrupt", epoch=e)
                         for e in range(64)), period=64)
    eng = ServeEngine(store, cfg=ExecConfig(routing="a2a"), caps=CAPS,
                      mesh=_mesh1(), fault_plan=fp, fault_retries=2,
                      max_escalations=0)
    res = eng.execute([CHAIN])[0]
    assert res.stats["fault_unrecovered"] is True
    assert res.rows_set(bnd.vars) <= want          # never a wrong row
    assert eng.fault_redispatches == 2             # budget exhausted


# ---------------------------------------------------------------------------
# satellite: unconditional per-step overflow on the plain local path
# ---------------------------------------------------------------------------


def test_step_overflow_flows_without_stats_instrumentation(rng):
    """execute_local's default (un-instrumented, no host syncs in the
    cascade) now attaches the cumulative per-step overflow scalars —
    escalation can localize the truncating step without stats=."""
    tr = random_graph(rng)
    store = build_store(tr, 1)
    bnd = execute_local(store, CHAIN, "mapsin", caps=TINY)
    assert hasattr(bnd, "step_overflow")
    plain = np.asarray(bnd.step_overflow)
    stats = []
    inst = execute_local(store, CHAIN, "mapsin", caps=TINY, stats=stats)
    assert plain.tolist() == np.asarray(inst.step_overflow).tolist()
    assert plain.shape[0] == len(stats)
    assert int(plain[-1]) == int(bnd.overflow)     # cumulative, total last
