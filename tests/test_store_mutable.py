"""Durable live ingest (DESIGN.md §9): the mutable store's query results
must be bit-identical to a fresh `build_store` over exactly the
acknowledged triples — after any sequence of ingests/flushes, after a
clean reopen, and after a crash at ANY byte boundary of the WAL. The
version-based invalidation satellites are covered here too: a post-ingest
submit can never reuse a pre-ingest compiled cascade, and stale planner
statistics may mis-price operators but never change results."""
import json
import os
import shutil

import numpy as np
import pytest

from repro.core import (Caps, Pattern, build_store, compile_plan,
                        execute_local, execute_oracle, rows_set)
from repro.core.planner import pattern_cardinality, relation_stats
from repro.core.rdf import MAX_ID, Dictionary, unpack3
from repro.obs.metrics import MetricsRegistry
from repro.serve import ServeEngine
from repro.serve.faults import (DurabilityFaultPlan, SimulatedCrash,
                                WalFault)
from repro.store import MutableTripleStore
from repro.store.wal import (HEADER_SIZE, REC_TRIPLES, WalWriter,
                             decode_triples_payload, encode_record,
                             encode_triples_payload, read_wal,
                             scan_records)

CAPS = Caps(scan_cap=4096, out_cap=4096, probe_cap=16, row_cap=64)
JOIN = (Pattern("?x", 1, "?y"), Pattern("?y", 2, "?z"))
SCAN = (Pattern("?x", 1, "?y"),)


def batches(seed, n_batches, per_batch, ids=30, preds=4):
    """Join-friendly random ingest workload (small id space, few preds)."""
    r = np.random.RandomState(seed)
    return [np.stack([r.randint(0, ids, per_batch),
                      r.randint(0, preds, per_batch),
                      r.randint(0, ids, per_batch)], 1).astype(np.int32)
            for _ in range(n_batches)]


def rows_of(store, pats, ovars):
    bnd = execute_local(store, pats, caps=CAPS)
    got = rows_set(np.asarray(bnd.table), np.asarray(bnd.valid),
                   len(bnd.vars))
    if tuple(bnd.vars) != tuple(ovars):
        perm = [bnd.vars.index(v) for v in ovars]
        got = set(tuple(r[i] for i in perm) for r in got)
    return got


def assert_matches_oracle(store, triples, pats=JOIN):
    """Recovered/mutated store answers == fresh build_store over
    `triples` (the acked set), for a join and a scan pattern."""
    for q in (pats, SCAN):
        want, ovars = execute_oracle(triples.astype(np.int32), q)
        assert rows_of(store, q, ovars) == want


def acked_triples(root, include_last_wal_bytes=None):
    """The oracle's input: snapshot base + every complete record in the
    WAL's durable prefix (optionally truncated to a byte budget)."""
    with open(os.path.join(root, "MANIFEST.json")) as f:
        man = json.load(f)
    parts = []
    if man["snapshot"]:
        with np.load(os.path.join(root, man["snapshot"])) as snap:
            base = snap["keys_spo"]
        if len(base):
            s, p, o = unpack3(base)
            parts.append(np.stack([s, p, o], 1))
    wal_path = os.path.join(root, man["wal"])
    data = open(wal_path, "rb").read() if os.path.exists(wal_path) else b""
    if include_last_wal_bytes is not None:
        data = data[:include_last_wal_bytes]
    for _off, _seq, rec_type, payload in scan_records(
            data, man["start_seq"]):
        if rec_type == REC_TRIPLES:
            parts.append(decode_triples_payload(payload))
    if not parts:
        return np.zeros((0, 3), np.int64)
    return np.concatenate([np.asarray(p, np.int64) for p in parts])


# ---------------------------------------------------------------------------
# WAL unit behavior
# ---------------------------------------------------------------------------


def test_wal_roundtrip(tmp_path):
    path = str(tmp_path / "w.log")
    w = WalWriter(path)
    payloads = [encode_triples_payload(np.array([[i, i + 1, i + 2]]))
                for i in range(5)]
    for p in payloads:
        w.append(REC_TRIPLES, p)
    w.sync()
    w.close()
    records, valid_end, last_seq = read_wal(path)
    assert last_seq == 4 and valid_end == os.path.getsize(path)
    assert [p for _s, _t, p in records] == payloads
    assert [s for s, _t, _p in records] == list(range(5))


def test_wal_torn_tail_stops_replay_and_is_repaired(tmp_path):
    path = str(tmp_path / "w.log")
    w = WalWriter(path)
    w.append(REC_TRIPLES, encode_triples_payload(np.array([[1, 2, 3]])))
    w.sync()
    w.close()
    good_size = os.path.getsize(path)
    torn = encode_record(1, REC_TRIPLES,
                         encode_triples_payload(np.array([[4, 5, 6]])))
    with open(path, "ab") as f:
        f.write(torn[:HEADER_SIZE + 5])     # payload never fully landed
    records, valid_end, last_seq = read_wal(path)
    assert len(records) == 1 and last_seq == 0 and valid_end == good_size
    # reopening repairs: the torn bytes are truncated, seq continues at 1
    w2 = WalWriter(path)
    assert os.path.getsize(path) == good_size and w2.next_seq == 1
    w2.close()


def test_wal_crc_corruption_stops_replay(tmp_path):
    path = str(tmp_path / "w.log")
    w = WalWriter(path)
    for i in range(3):
        w.append(REC_TRIPLES,
                 encode_triples_payload(np.array([[i, i, i]])))
    w.sync()
    w.close()
    data = bytearray(open(path, "rb").read())
    # flip one payload byte of the SECOND record
    rec_len = len(data) // 3
    data[rec_len + HEADER_SIZE + 2] ^= 0xFF
    open(path, "wb").write(bytes(data))
    records, _end, last_seq = read_wal(path)
    assert len(records) == 1 and last_seq == 0   # stops AT the bad record


# ---------------------------------------------------------------------------
# ingest == oracle, flush exactness, input validation
# ---------------------------------------------------------------------------


def test_ingest_across_flushes_matches_oracle(tmp_path):
    st = MutableTripleStore.create(str(tmp_path / "s"), num_shards=4,
                                   overlay_limit=16)
    acked = []
    for b in batches(0, 10, 20):
        st.ingest(b)
        acked.append(b)
    assert st.flush_count > 0                    # the limit actually bound
    assert_matches_oracle(st, np.concatenate(acked))
    st.close()


def test_explicit_flush_drains_overlay_exactly(tmp_path):
    st = MutableTripleStore.create(str(tmp_path / "s"), num_shards=2,
                                   overlay_limit=4096)
    acked = []
    for b in batches(1, 4, 25):
        st.ingest(b)
        acked.append(b)
    assert st.overlay_depth > 0
    st.flush()
    assert st.overlay_depth == 0
    assert_matches_oracle(st, np.concatenate(acked))
    st.close()


def test_duplicate_reingest_is_content_noop(tmp_path):
    st = MutableTripleStore.create(str(tmp_path / "s"), num_shards=1)
    b = batches(2, 1, 30)[0]
    st.ingest(b)
    n = st.n_triples
    st.ingest(b)                                 # acked again, same set
    assert st.n_triples == n
    assert_matches_oracle(st, b)
    st.close()


def test_ingest_rejects_unstorable_batches(tmp_path):
    st = MutableTripleStore.create(str(tmp_path / "s"))
    wal0 = st.wal_bytes
    for bad in (np.zeros((0, 3), np.int32),
                np.array([[-1, 0, 0]]),
                np.array([[0, MAX_ID + 1, 0]]),
                np.array([[MAX_ID, MAX_ID, MAX_ID]])):
        with pytest.raises(ValueError):
            st.ingest(bad)
    # a rejected batch is never acknowledged: nothing reached the WAL
    assert st.wal_bytes == wal0 and st.n_triples == 0
    st.close()


def test_create_refuses_existing_store(tmp_path):
    root = str(tmp_path / "s")
    MutableTripleStore.create(root).close()
    with pytest.raises(ValueError):
        MutableTripleStore.create(root)


# ---------------------------------------------------------------------------
# recovery: clean reopen + truncation sweep + crash injection
# ---------------------------------------------------------------------------


def test_clean_reopen_matches_oracle(tmp_path):
    root = str(tmp_path / "s")
    st = MutableTripleStore.create(root, num_shards=4, overlay_limit=16)
    acked = []
    for b in batches(3, 8, 20):
        st.ingest(b)
        acked.append(b)
    st.close()
    st2 = MutableTripleStore.open(root, overlay_limit=16)
    assert_matches_oracle(st2, np.concatenate(acked))
    # version continuity: the reopened store's version reflects history
    assert st2.store_version > 0
    st2.close()


def _truncation_sweep(root, cuts):
    """Recover from a WAL truncated at each byte offset in `cuts`; assert
    results equal the oracle over exactly the records that survived."""
    with open(os.path.join(root, "MANIFEST.json")) as f:
        man = json.load(f)
    wal_path = os.path.join(root, man["wal"])
    data = open(wal_path, "rb").read()
    for cut in cuts:
        work = root + f"_cut{cut}"
        shutil.rmtree(work, ignore_errors=True)
        shutil.copytree(root, work)
        with open(os.path.join(work, man["wal"]), "wb") as f:
            f.write(data[:cut])
        st = MutableTripleStore.open(work)
        assert_matches_oracle(st, acked_triples(root, cut))
        st.close()
        shutil.rmtree(work, ignore_errors=True)


def _record_boundaries(root):
    with open(os.path.join(root, "MANIFEST.json")) as f:
        man = json.load(f)
    data = open(os.path.join(root, man["wal"]), "rb").read()
    bounds = [0]
    for off, _seq, _t, payload in scan_records(data, man["start_seq"]):
        bounds.append(off + HEADER_SIZE + len(payload) + 4)
    return bounds, len(data)


def test_truncation_sweep_every_boundary_and_midrecord(tmp_path):
    """The tentpole property at small N: every record boundary, plus
    mid-header / mid-payload / mid-crc cuts inside every record."""
    root = str(tmp_path / "s")
    st = MutableTripleStore.create(root, num_shards=2, overlay_limit=4096)
    for b in batches(4, 5, 12):
        st.ingest(b)
    st.close()
    bounds, size = _record_boundaries(root)
    assert len(bounds) == 6 and bounds[-1] == size
    cuts = set(bounds)
    for lo, hi in zip(bounds, bounds[1:]):       # inside every record
        cuts.update([lo + 3, lo + HEADER_SIZE + 1, hi - 2])
    _truncation_sweep(root, sorted(cuts))


def test_unacked_triples_never_appear(tmp_path):
    """A triple whose record was torn must be absent after recovery."""
    root = str(tmp_path / "s")
    st = MutableTripleStore.create(root, num_shards=1)
    st.ingest(np.array([[1, 1, 1]], np.int32))
    st.ingest(np.array([[7, 1, 9]], np.int32))   # the record to tear
    st.close()
    bounds, _size = _record_boundaries(root)
    with open(os.path.join(root, "MANIFEST.json")) as f:
        man = json.load(f)
    wal_path = os.path.join(root, man["wal"])
    data = open(wal_path, "rb").read()
    with open(wal_path, "wb") as f:
        f.write(data[:bounds[2] - 1])            # 1 byte short of complete
    st2 = MutableTripleStore.open(root)
    want, ovars = execute_oracle(np.array([[1, 1, 1]], np.int32), SCAN)
    assert rows_of(st2, SCAN, ovars) == want     # only the acked triple
    assert st2.n_triples == 1
    st2.close()


@pytest.mark.parametrize("seed", range(6))
def test_injected_crash_recovers_to_acked_prefix(tmp_path, seed):
    """Seeded chaos: torn writes / lost unsynced bytes / plain crashes at
    sampled records — recovery equals the oracle over what was acked
    BEFORE the crash, never more."""
    root = str(tmp_path / f"s{seed}")
    plan = DurabilityFaultPlan.sample(seed, horizon=8)
    st = MutableTripleStore.create(root, num_shards=2, overlay_limit=32,
                                   fault_plan=plan)
    acked = []
    crashed = False
    try:
        for b in batches(seed, 10, 8):
            st.ingest(b)
            acked.append(b)
    except SimulatedCrash:
        crashed = True
    assert crashed                               # horizon < records written
    st2 = MutableTripleStore.open(root)
    survivors = (np.concatenate(acked) if acked
                 else np.zeros((0, 3), np.int64))
    assert_matches_oracle(st2, survivors)
    st2.close()


def test_crash_during_flush_window_recovers(tmp_path):
    """Kill between the snapshot write and the manifest commit: recovery
    must use the OLD snapshot + OLD WAL and still equal the oracle."""
    root = str(tmp_path / "s")
    st = MutableTripleStore.create(root, num_shards=2, overlay_limit=4096)
    acked = []
    for b in batches(5, 3, 15):
        st.ingest(b)
        acked.append(b)
    # simulate the pre-commit half of a flush: write the snapshot file the
    # next flush WOULD write, then "crash" (never touch the manifest)
    seq = st.acked_seq + 1
    merged = acked_triples(root)
    snap = build_store(merged.astype(np.int32), 1)
    del snap  # (content irrelevant — an orphan file must simply be ignored)
    open(os.path.join(root, f"snap-{seq}.npz"), "wb").write(b"orphan")
    st.close()
    st2 = MutableTripleStore.open(root)
    assert_matches_oracle(st2, np.concatenate(acked))
    st2.close()


@pytest.mark.slow
def test_truncation_sweep_every_byte_at_scale(tmp_path):
    """Every byte offset of a multi-record WAL over a snapshot base. Per
    byte, the recovered index CONTENTS (base ∪ overlay key sets of both
    indexes) must equal `build_store` over the acked prefix — query
    results are pure functions of those sorted key arrays, so content
    equality is the bit-identical-results property; full query execution
    additionally runs at every record boundary."""
    from repro.core.rdf import pack3
    root = str(tmp_path / "s")
    st = MutableTripleStore.create(root, num_shards=4, overlay_limit=64)
    for b in batches(6, 6, 40):
        st.ingest(b)
    st.flush()                                   # put a snapshot underneath
    for b in batches(7, 4, 25):
        st.ingest(b)
    st.close()
    bounds, size = _record_boundaries(root)
    with open(os.path.join(root, "MANIFEST.json")) as f:
        man = json.load(f)
    data = open(os.path.join(root, man["wal"]), "rb").read()
    for cut in range(size + 1):
        work = root + "_cut"
        shutil.rmtree(work, ignore_errors=True)
        shutil.copytree(root, work)
        with open(os.path.join(work, man["wal"]), "wb") as f:
            f.write(data[:cut])
        st2 = MutableTripleStore.open(work)
        t = acked_triples(root, cut)
        want_spo = np.unique(pack3(t[:, 0], t[:, 1], t[:, 2]))
        want_ops = np.unique(pack3(t[:, 2], t[:, 1], t[:, 0]))
        got_spo = np.sort(np.concatenate([st2._bk_spo, st2._ov_spo]))
        got_ops = np.sort(np.concatenate([st2._bk_ops, st2._ov_ops]))
        assert np.array_equal(got_spo, want_spo), f"cut={cut}"
        assert np.array_equal(got_ops, want_ops), f"cut={cut}"
        st2.close()
        shutil.rmtree(work, ignore_errors=True)
    _truncation_sweep(root, bounds)              # full queries per record


# ---------------------------------------------------------------------------
# satellite 1: version-keyed compile caches
# ---------------------------------------------------------------------------


def test_layout_key_incorporates_store_version(tmp_path):
    st = MutableTripleStore.create(str(tmp_path / "s"), num_shards=2)
    st.ingest(batches(8, 1, 10)[0])
    k1 = st.layout_key
    st.ingest(np.array([[3, 3, 3]], np.int32))
    k2 = st.layout_key
    assert k1 != k2 and k2[0] > k1[0]
    st.close()


def test_engine_never_reuses_preingest_cascade(tmp_path):
    """The regression the satellite names: submit, ingest triples that
    CHANGE the answer, submit again — the second submit must recompile
    (compile-miss counter) and return the post-ingest rows."""
    reg = MetricsRegistry()
    st = MutableTripleStore.create(str(tmp_path / "s"), num_shards=1,
                                   overlay_limit=4096, metrics=reg)
    st.ingest(np.array([[1, 1, 2], [2, 2, 3]], np.int32))
    eng = ServeEngine(st, caps=CAPS, metrics=reg)
    pats = list(JOIN)
    res1 = eng.execute([pats])[0]
    misses1 = reg.counter("serve_compile_cache_misses_total").value
    assert res1.rows_set(("?x", "?y", "?z")) == {(1, 2, 3)}
    # repeat without mutation: cached (no new compile)
    eng.execute([pats])
    assert reg.counter("serve_compile_cache_misses_total").value == misses1
    # ingest an answer-changing triple: MUST miss and see the new row
    st.ingest(np.array([[5, 1, 2]], np.int32))
    res2 = eng.execute([pats])[0]
    assert reg.counter("serve_compile_cache_misses_total").value > misses1
    assert res2.rows_set(("?x", "?y", "?z")) == {(1, 2, 3), (5, 2, 3)}
    st.close()


# ---------------------------------------------------------------------------
# satellite 2: plan_cache / relation_stats invalidation
# ---------------------------------------------------------------------------


def test_plan_cache_and_relstats_invalidated_on_mutation(tmp_path):
    st = MutableTripleStore.create(str(tmp_path / "s"), num_shards=1)
    st.ingest(batches(9, 1, 40)[0])
    pat = Pattern("?x", 1, "?y")
    card1 = pattern_cardinality(st, pat)
    stats1 = relation_stats(st, pat, ())
    assert ("card", pat) in st.plan_cache        # memoized
    st.ingest(np.array([[25, 1, 26], [26, 1, 27]], np.int32))
    assert ("card", pat) not in st.plan_cache    # wholesale clear
    card2 = pattern_cardinality(st, pat)
    stats2 = relation_stats(st, pat, ())
    assert card2 == card1 + 2                    # stats see the new rows
    assert stats2[0] == stats1[0] + 2
    st.close()


def test_stale_plan_still_exact_after_mutation(tmp_path):
    """A PhysicalPlan compiled against pre-ingest statistics may mis-price
    operators, but executing it on the mutated store must still return
    the post-ingest oracle rows."""
    st = MutableTripleStore.create(str(tmp_path / "s"), num_shards=1)
    acked = [batches(10, 1, 40)[0]]
    st.ingest(acked[0])
    stale_plan = compile_plan(st, JOIN, CAPS)
    acked.append(batches(11, 1, 40, ids=30)[0])
    st.ingest(acked[1])
    want, ovars = execute_oracle(np.concatenate(acked).astype(np.int32),
                                 JOIN)
    bnd = execute_local(st, stale_plan)
    got = rows_set(np.asarray(bnd.table), np.asarray(bnd.valid),
                   len(bnd.vars))
    if tuple(bnd.vars) != tuple(ovars):
        perm = [bnd.vars.index(v) for v in ovars]
        got = set(tuple(r[i] for i in perm) for r in got)
    assert got == want and len(want) > 0
    st.close()


# ---------------------------------------------------------------------------
# dictionary growth through the WAL
# ---------------------------------------------------------------------------


def test_dictionary_grows_durably(tmp_path):
    root = str(tmp_path / "s")
    st = MutableTripleStore.create(root, num_shards=1, overlay_limit=8)
    st.ingest_terms([("alice", "knows", "bob"), ("bob", "knows", "carol")])
    st.ingest_terms([("carol", "knows", "alice"),
                     ("alice", "likes", "jazz")])
    st.flush()                                   # terms fold into snapshot
    st.ingest_terms([("dave", "knows", "alice")])  # terms in the new WAL
    terms = st.dictionary.terms()
    st.close()
    st2 = MutableTripleStore.open(root)
    assert st2.dictionary.terms() == terms
    pats = (st2.dictionary.pattern("?a", "knows", "?b"),)
    want, ovars = execute_oracle(
        st2.dictionary.encode_triples(
            [("alice", "knows", "bob"), ("bob", "knows", "carol"),
             ("carol", "knows", "alice"), ("dave", "knows", "alice")]),
        pats)
    assert rows_of(st2, pats, ovars) == want and len(want) == 4
    st2.close()


def test_dictionary_replay_is_idempotent_and_checked():
    d = Dictionary()
    d.replay_term(0, "a")
    d.replay_term(0, "a")                        # idempotent
    assert len(d) == 1 and d.id("a") == 0
    with pytest.raises(ValueError):
        d.replay_term(0, "b")                    # conflict
    with pytest.raises(ValueError):
        d.replay_term(5, "z")                    # gap


# ---------------------------------------------------------------------------
# observability
# ---------------------------------------------------------------------------


def test_store_metrics_published(tmp_path):
    reg = MetricsRegistry()
    root = str(tmp_path / "s")
    st = MutableTripleStore.create(root, num_shards=2, overlay_limit=8,
                                   metrics=reg)
    for b in batches(12, 4, 10):
        st.ingest(b)
    assert reg.counter("store_ingest_batches_total").value == 4
    assert reg.counter("store_ingest_triples_total").value == 40
    assert reg.counter("store_flush_total").value == st.flush_count > 0
    assert reg.gauge("store_overlay_depth").value == st.overlay_depth
    assert reg.gauge("store_wal_bytes").value == st.wal_bytes > 0
    st.close()
    reg2 = MetricsRegistry()
    st2 = MutableTripleStore.open(root, metrics=reg2)
    assert reg2.gauge("store_recovery_seconds").value > 0
    st2.close()


# ---------------------------------------------------------------------------
# serving the mutating store on the sharded engine path (degenerate
# single-device mesh: the fast-tier stand-in for test_multidevice)
# ---------------------------------------------------------------------------


def test_sharded_engine_serves_across_ingests(tmp_path):
    import jax
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    st = MutableTripleStore.create(str(tmp_path / "s"), num_shards=1,
                                   overlay_limit=32)
    eng = ServeEngine(st, caps=CAPS, mesh=mesh)
    acked = []
    for b in batches(13, 4, 20):
        st.ingest(b)
        acked.append(b)
        res = eng.execute([list(JOIN)])[0]
        want, ovars = execute_oracle(np.concatenate(acked), JOIN)
        assert res.rows_set(ovars) == want
    st.close()
