"""§Perf hillclimb driver: three cells, hypothesis -> change -> re-lower ->
measure (analytic terms + compiled-HLO collective inventory + residency).

Run:  PYTHONPATH=src python experiments/perf_iterations.py
Artifacts: experiments/dryrun/*_<tag>.json + experiments/perf_results.json
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import dryrun  # noqa: E402  (sets XLA_FLAGS=512 devices)
from repro.configs import SHAPES, get_config  # noqa: E402
from repro.launch.costmodel import cost_cell  # noqa: E402
from repro.common import dump_json  # noqa: E402

SINGLE = {"data": 16, "model": 16}
RESULTS = []


def record(name, cell, base_terms, new_terms, base_rep, new_rep, hypothesis,
           confirmed, note):
    RESULTS.append({
        "iteration": name, "cell": cell, "hypothesis": hypothesis,
        "before": base_terms, "after": new_terms,
        "hlo_collective_before": base_rep["collective_bytes"],
        "hlo_collective_after": new_rep["collective_bytes"],
        "resid_before_gib": base_rep["analytic_memory"]["total"] / 2**30,
        "resid_after_gib": new_rep["analytic_memory"]["total"] / 2**30,
        "confirmed": confirmed, "note": note,
    })
    print(f"[perf] {name}: dominant {base_terms['dominant']}"
          f" {base_terms['step_s']:.3e}s -> {new_terms['dominant']}"
          f" {new_terms['step_s']:.3e}s | roofline_frac"
          f" {base_terms['roofline_fraction']:.3f} ->"
          f" {new_terms['roofline_fraction']:.3f} | {confirmed}")


def terms_of(arch, shape_name, mesh_shape, micro, **kw):
    cfg = get_config(arch)
    c = cost_cell(cfg, SHAPES[shape_name], mesh_shape, micro, **kw)
    t = c.terms(256)
    t["coll_bytes"] = c.coll_bytes
    t["hbm_bytes"] = c.hbm_bytes
    return t


def main():
    # ---------------- Iteration A: xlstm-125m train_4k ----------------
    # Worst roofline fraction (0.05). Hypothesis: a 125M model on a 16x16
    # mesh is over-tensor-parallelized — 3 TP combines/layer cost more链
    # bytes than the whole FSDP stream. Napkin: TP coll ~ 3L*T_act*(tp-1)*3
    # = 3*12*4096*2B*1M tokens... >> params (0.25GB). Change: dp_heavy rules
    # (batch over data x model, zero TP). Expect collective -> ~FSDP-only,
    # compute-bound cell.
    base = dryrun.run_cell("xlstm-125m", "train_4k", False)
    new = dryrun.run_cell("xlstm-125m", "train_4k", False, tag="dp_heavy",
                          rules_overrides={"dp_heavy": True})
    bt = terms_of("xlstm-125m", "train_4k", SINGLE, 16)
    nt = terms_of("xlstm-125m", "train_4k", {"data": 256, "model": 1}, 1)
    record("A.dp_heavy", "xlstm-125m/train_4k", bt, nt,
           base, new,
           "125M model over-TP'd: 3 TP combines/layer dominate; remap model "
           "axis to data parallelism",
           "confirmed" if nt["step_s"] < 0.5 * bt["step_s"] else "refuted",
           "batch 256 over all 256 chips; params FSDP over data only")

    # ---------------- Iteration B: dbrx-132b train_4k -----------------
    # Most collective-bound (25.2s vs 7.6s compute). Hypothesis: 16 experts
    # don't divide 256 chips, so expert weights (97% of params) sat on the
    # model axis ONLY and their d_model dim was FSDP-gathered over data every
    # microbatch: 264GB*3passes*16micro*15 links. Change: experts over the
    # 16-way DATA axis + d_ff TP over model -> expert weights fully sharded,
    # zero expert FSDP gathers; tokens route via a2a (the MAPSIN economy).
    base = dryrun.run_cell("dbrx-132b", "train_4k", False)
    new = dryrun.run_cell("dbrx-132b", "train_4k", False, tag="ep_data")
    bt = terms_of("dbrx-132b", "train_4k", SINGLE, 16, assume_ep=False)
    nt = terms_of("dbrx-132b", "train_4k", SINGLE, 16, assume_ep=True)
    record("B.ep_data", "dbrx-132b/train_4k", bt, nt, base, new,
           "expert-weight FSDP gathers dominate; full-shard experts over "
           "(data x model), ship routed tokens instead of weights",
           "confirmed" if nt["collective_s"] < 0.5 * bt["collective_s"] else "refuted",
           "experts->data axis, d_ff->model (rules change is now the default "
           "— the tagged artifact equals the new baseline)")

    # ---------------- Iteration C: qwen3-8b decode_32k ----------------
    # Memory-bound serve cell of the arch that exercises the paper's
    # technique (mapsin vocab-sharded embedding). Hypothesis: each of the 16
    # data replicas streams the full TP slice of the MLP (2/3 of weights)
    # every step; sharding d_ff over data x model streams it once.
    # Expect memory term ~ /2.3; tiny decode activations make the extra
    # all-reduce negligible.
    base = dryrun.run_cell("qwen3-8b", "decode_32k", False)
    new = dryrun.run_cell("qwen3-8b", "decode_32k", False, tag="wide_mlp",
                          rules_overrides={"wide_mlp_serve": True})
    bt = terms_of("qwen3-8b", "decode_32k", SINGLE, 1)
    nt = terms_of("qwen3-8b", "decode_32k", SINGLE, 1, wide_mlp=True)
    record("C.wide_mlp", "qwen3-8b/decode_32k", bt, nt, base, new,
           "decode streams MLP weights once per data replica; wide-TP the "
           "d_ff dim over all 256 chips",
           "confirmed" if nt["memory_s"] < 0.6 * bt["memory_s"] else "refuted",
           "weights resident/chip also drop 16x for the MLP slice")

    dump_json(RESULTS, os.path.join(os.path.dirname(__file__),
                                    "perf_results.json"))
    print(f"[perf] wrote {len(RESULTS)} iterations")


if __name__ == "__main__":
    main()
