"""Logical-axis -> mesh-axis sharding rules (DP / FSDP / TP / EP).

Every parameter and activation is annotated with *logical* axis names; a
``Rules`` object (built per mesh + model) resolves them to a
``PartitionSpec``. This keeps model code mesh-agnostic: the same model
lowers on 1 CPU device, a 16x16 pod, or the 2x16x16 multi-pod mesh.

Axis vocabulary
  batch      activation batch            -> (pod, data)
  seq        sequence                    -> () (context-parallel variant: model)
  embed      activation hidden dim       -> ()
  heads      attention query heads       -> model
  kv_heads   attention kv heads          -> model (or () in head_dim mode)
  head_dim   per-head dim                -> () (or model in head_dim mode)
  mlp        FFN hidden                  -> model
  vocab      vocabulary                  -> model
  experts    MoE experts (EP)            -> model
  fsdp       parameter shard dim (ZeRO)  -> data (+pod if fsdp_pod)
  layers     scan-stacked layer dim      -> ()
  lru        RG-LRU width                -> model
  inner      xLSTM inner dim             -> model
  window     local-attention window      -> ()
  kv_lora/q_lora/rope  MLA compressed dims -> ()
"""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class Rules:
    mesh: Mesh
    fsdp: bool = True
    fsdp_pod: bool = False       # also shard params over the pod axis
    kv_mode: str = "kv_heads"    # kv_heads | head_dim  (see choose_kv_mode)
    shard_batch: bool = True     # False for global_batch < data axis (long_500k)
    seq_shard: bool = False      # context parallelism over the model axis
    serve: bool = False          # inference: no FSDP (weights stream per step)
    num_experts: int = 0         # EP across (data x model) when experts allow
    dp_heavy: bool = False       # small models: no TP — batch over ALL axes
    wide_mlp_serve: bool = False  # serve: shard d_ff over data x model

    def __post_init__(self):
        axes = self.mesh.axis_names
        has_pod = "pod" in axes
        has_data = "data" in axes
        has_model = "model" in axes
        model = ("model",) if has_model else ()
        data = ("data",) if has_data else ()
        pod = ("pod",) if has_pod else ()
        if self.dp_heavy:
            # §Perf iteration A: small models waste the interconnect on TP
            # combines — treat the model axis as extra data parallelism
            batch = (pod + data + model) if self.shard_batch else ()
            model = ()
        else:
            batch = (pod + data) if self.shard_batch else ()
        if self.serve:
            fsdp = ()  # inference never gathers FSDP shards per step
        else:
            fsdp = (pod + data) if (self.fsdp and self.fsdp_pod) else data if self.fsdp else ()
        # expert parallelism: spread experts over as many axes as divide the
        # expert count — EP weights never move, only routed tokens do (the
        # MAPSIN economy). deepseek-v3: 256 experts over 256 chips; dbrx:
        # 16 experts over the 16-way data axis (+ d_ff TP over model).
        ep = ()
        for cand in (data + model, data, model):
            n = 1
            for a in cand:
                n *= self.mesh.shape[a]
            if cand and self.num_experts and self.num_experts % max(n, 1) == 0:
                ep = cand
                break
        mlp = (data + model) if (self.serve and self.wide_mlp_serve) else model
        kv_on_heads = self.kv_mode == "kv_heads"
        self._map: dict[str | None, tuple[str, ...]] = {
            None: (), "layers": (), "stack": (), "window": (),
            "batch": batch,
            "seq": model if self.seq_shard else (),
            # remat-saved layer inputs: always sequence-sharded over `model`
            "seq_ckpt": model,
            "embed": (),
            "heads": model if kv_on_heads else (),
            "kv_heads": model if kv_on_heads else (),
            "head_dim": () if kv_on_heads else model,
            "mlp": mlp,
            "vocab": model,
            "experts": ep,
            # MLA latent KV cache: shard the sequence dim over `model`
            # (scores/softmax reduce over it -> psum), since the latent has
            # no head dim to split
            "seq_kv": model,
            "fsdp": fsdp,
            "lru": model,
            "inner": model,
            "kv_lora": (), "q_lora": (), "rope": (),
            # MoE per-expert buffers: capacity dim shards over the DP axes
            "capacity": batch,
        }

    def pspec(self, *axes: str | None) -> P:
        parts = []
        used: set[str] = set()
        for a in axes:
            mesh_axes = tuple(m for m in self._map[a] if m not in used)
            used.update(mesh_axes)
            if len(mesh_axes) == 0:
                parts.append(None)
            elif len(mesh_axes) == 1:
                parts.append(mesh_axes[0])
            else:
                parts.append(mesh_axes)
        return P(*parts)

    def sharding(self, *axes: str | None) -> NamedSharding:
        return NamedSharding(self.mesh, self.pspec(*axes))


def choose_kv_mode(num_kv_heads: int, mesh: Mesh) -> str:
    """Shard kv heads over `model` when divisible; otherwise shard head_dim.

    GQA models with few kv heads (kv=1..8) cannot split kv 16-way; sharding
    head_dim instead keeps all chips busy at the cost of an all-reduce over
    the contracted dim in attention (surfaced by the roofline; see §Perf).
    """
    if "model" not in mesh.axis_names:
        return "kv_heads"
    msize = mesh.shape["model"]
    return "kv_heads" if num_kv_heads % msize == 0 else "head_dim"


def make_rules(mesh: Mesh, cfg=None, shape=None, **overrides) -> Rules:
    kw: dict = {}
    if cfg is not None:
        kw["kv_mode"] = choose_kv_mode(cfg.num_kv_heads, mesh)
        kw["num_experts"] = cfg.num_experts
    if shape is not None and "data" in mesh.axis_names:
        dp = mesh.shape["data"] * mesh.shape.get("pod", 1)
        kw["shard_batch"] = shape.global_batch >= dp
        kw["serve"] = shape.kind != "train"
    if cfg is not None and "pod" in mesh.axis_names:
        # very large models: FSDP over pod axis too (memory floor)
        kw["fsdp_pod"] = cfg.n_params() > 100e9
    kw.update(overrides)
    return Rules(mesh, **kw)


def single_device_mesh() -> Mesh:
    return Mesh([jax.devices()[0]], ("data",))
