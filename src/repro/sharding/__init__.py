from repro.sharding.rules import Rules, choose_kv_mode, make_rules, single_device_mesh  # noqa: F401
