from repro.optim.adamw import (  # noqa: F401
    OptConfig, adamw_update, cosine_lr, global_norm, init_opt_state,
    opt_state_defs,
)
