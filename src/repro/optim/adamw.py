"""AdamW with global-norm clipping and dtype-configurable moments.

Moment dtype bf16 ("gradient-state compression") halves optimizer HBM and
checkpoint bytes — one of the distributed-optimization levers in §Perf.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.common import dtype_of


@dataclasses.dataclass(frozen=True)
class OptConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"  # "bfloat16" = compressed optimizer states


def cosine_lr(cfg: OptConfig, step: jax.Array) -> jax.Array:
    warm = cfg.learning_rate * jnp.minimum(step, cfg.warmup_steps) / cfg.warmup_steps
    t = jnp.clip((step - cfg.warmup_steps) /
                 jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.learning_rate * cos)


def init_opt_state(params: Any, cfg: OptConfig) -> dict:
    mdt = dtype_of(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads: Any, state: dict, params: Any, cfg: OptConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = cosine_lr(cfg, step)
    mdt = dtype_of(cfg.moment_dtype)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu32 = cfg.b1 * mu.astype(jnp.float32) + (1 - cfg.b1) * g
        nu32 = cfg.b2 * nu.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        mhat = mu32 / b1c
        nhat = nu32 / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), mu32.astype(mdt), nu32.astype(mdt)

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"mu": new_mu, "nu": new_nu, "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


def opt_state_defs(param_defs: Any, cfg: OptConfig) -> dict:
    """ParamDef tree for the optimizer state (same sharding as params)."""
    import dataclasses as dc
    from repro.models.params import ParamDef, pdef
    from repro.common import tree_map_with_path

    def mom(_, d: ParamDef):
        return dc.replace(d, dtype=cfg.moment_dtype, init="zeros")
    return {
        "mu": tree_map_with_path(mom, param_defs),
        "nu": tree_map_with_path(mom, param_defs),
        "step": pdef((), (), "int32", "zeros"),
    }
