"""Fault-tolerant training runtime.

Design for 1000+ nodes (DESIGN.md §4):
  * deterministic stateless data (re-derive any batch from the step index)
  * async atomic checkpoints every `ckpt_every` steps
  * restart = load latest checkpoint + continue (bit-exact; tested by
    killing mid-run and comparing against an uninterrupted run)
  * elastic restore onto a different mesh (global-shape checkpoints)
  * straggler watchdog: per-step wall-time EWMA; steps exceeding
    `straggler_factor` x median are flagged and (at cluster scale) would
    trigger preemptive restart from the last checkpoint — on one host we
    surface the signal and count events.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint.checkpoint import AsyncCheckpointer, latest, load
from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.lm_data import batch_for_step
from repro.models import build_model, make_train_step
from repro.models.params import init_tree
from repro.optim import OptConfig, init_opt_state


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class StragglerWatchdog:
    factor: float = 3.0
    _times: list = dataclasses.field(default_factory=list)
    events: int = 0

    def observe(self, dt: float) -> bool:
        self._times.append(dt)
        med = float(np.median(self._times[-50:]))
        slow = len(self._times) > 5 and dt > self.factor * med
        if slow:
            self.events += 1
        return slow


class Trainer:
    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, workdir: str,
                 opt_cfg: OptConfig = OptConfig(), ckpt_every: int = 10,
                 seed: int = 0, mesh=None, rules=None):
        self.cfg, self.shape, self.workdir = cfg, shape, workdir
        self.opt_cfg, self.ckpt_every, self.seed = opt_cfg, ckpt_every, seed
        self.model = build_model(cfg, mesh, rules)
        self.step_fn = jax.jit(make_train_step(self.model, opt_cfg), donate_argnums=(0, 1))
        self.ckpt = AsyncCheckpointer(workdir)
        self.watchdog = StragglerWatchdog()

    def init_state(self):
        params = init_tree(self.model.param_defs(), jax.random.key(self.seed))
        return params, init_opt_state(params, self.opt_cfg)

    def restore_or_init(self):
        path = latest(self.workdir)
        params, opt_state = self.init_state()
        if path is None:
            return 0, params, opt_state
        step, trees = load(path, {"params": params, "opt_state": opt_state})
        return step, trees["params"], trees["opt_state"]

    def run(self, num_steps: int, fail_at: int | None = None,
            hook: Callable[[int, dict], None] | None = None):
        """Run (or resume) to `num_steps`. Raises SimulatedFailure at step
        `fail_at` AFTER some un-checkpointed progress — the crash test."""
        start, params, opt_state = self.restore_or_init()
        metrics: dict[str, Any] = {}
        for step in range(start, num_steps):
            if fail_at is not None and step == fail_at:
                raise SimulatedFailure(f"injected failure at step {step}")
            batch = {k: jax.numpy.asarray(v) for k, v in
                     batch_for_step(self.cfg, self.shape, step, self.seed).items()}
            t0 = time.perf_counter()
            params, opt_state, metrics = self.step_fn(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            self.watchdog.observe(time.perf_counter() - t0)
            if hook:
                hook(step, metrics)
            if (step + 1) % self.ckpt_every == 0:
                self.ckpt.save(step + 1, {"params": params,
                                          "opt_state": opt_state})
        self.ckpt.wait()
        return params, opt_state, metrics
