from repro.runtime.trainer import SimulatedFailure, StragglerWatchdog, Trainer  # noqa: F401
