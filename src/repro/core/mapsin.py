"""MAPSIN join — map-side index nested-loop join (paper §4), local primitives.

Everything here operates on one shard's data with static shapes:
  * ``Bindings`` — a fixed-capacity multiset of solution mappings
    (MapReduce's unbounded lists -> capacity + validity mask + overflow
    counter; overflow is *surfaced*, never silent).
  * ``scan_pattern``    — the distributed-table-scan input phase (§4.1 step 1+2)
  * ``probe``           — the index GET: binary-search range + gather + filter
  * ``mapsin_step``     — Algorithm 1 (one cascading iteration)
  * ``multiway_step``   — Algorithms 2+3 (star joins, single row-GET)

The distributed versions in core/distributed.py wrap these in shard_map.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.core.plan import (PatternPlan, make_plan, probe_ranges,
                             residual_values, row_range)
from repro.core.rdf import unpack3


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Bindings:
    """Fixed-capacity multiset of solution mappings Omega."""
    vars: tuple[str, ...]          # static aux
    table: jnp.ndarray             # (cap, n_vars) int32
    valid: jnp.ndarray             # (cap,) bool
    overflow: jnp.ndarray          # () int32 — dropped rows (capacity misses)

    def tree_flatten(self):
        return (self.table, self.valid, self.overflow), self.vars

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(aux, *children)

    @property
    def capacity(self) -> int:
        return self.table.shape[0]

    def count(self) -> jnp.ndarray:
        return jnp.sum(self.valid.astype(jnp.int32))

    @classmethod
    def empty(cls, vars: Sequence[str], cap: int) -> "Bindings":
        return cls(tuple(vars), jnp.zeros((cap, len(vars)), jnp.int32),
                   jnp.zeros((cap,), bool), jnp.zeros((), jnp.int32))


def compact(rows: jnp.ndarray, valid: jnp.ndarray, out_cap: int,
            buf: jnp.ndarray | None = None):
    """Pack valid rows (N, nv) to the front of a (out_cap, nv) buffer.

    Returns (table, valid_mask, n_dropped). When `buf` (a zeroed
    (out_cap, nv) array, e.g. a donated scratch Bindings table) is given,
    it supplies the padding slots — no fresh allocation.

    GATHER-formulated: the running count c = cumsum(valid) is
    non-decreasing, so the source row of output slot p (the (p+1)-th
    valid row) is ``searchsorted(c, p, side="right")`` — O(out_cap log N)
    rank-finds plus an out_cap-row gather. The former positional scatter
    of all N rows was the dominant cascade cost on CPU hosts (XLA
    serializes scatters); results are bit-identical.
    """
    if buf is None:
        buf = jnp.zeros((out_cap, rows.shape[1]), rows.dtype)
    if valid.shape[0] == 0:
        return buf, jnp.zeros((out_cap,), bool), jnp.zeros((), jnp.int32)
    c = jnp.cumsum(valid.astype(jnp.int32))                # running count
    total = c[-1]
    dropped = jnp.maximum(total - out_cap, 0)
    src = jnp.searchsorted(c, jnp.arange(out_cap, dtype=jnp.int32),
                           side="right")
    src = jnp.minimum(src, valid.shape[0] - 1)
    vmask = jnp.arange(out_cap) < jnp.minimum(total, out_cap)
    out = jnp.where(vmask[:, None], rows[src], buf)
    return out, vmask, dropped


# ---------------------------------------------------------------------------
# Index probes (HBase GET with predicate push-down)
# ---------------------------------------------------------------------------


def searchsorted(keys: jnp.ndarray, queries: jnp.ndarray,
                 impl: str = "jnp") -> jnp.ndarray:
    if impl == "pallas_interpret":
        from repro.kernels import ops
        return ops.searchsorted(keys, queries, interpret=True)
    return jnp.searchsorted(keys, queries)


def gather_range(keys: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray,
                 cap: int, impl: str = "jnp"):
    """For each probe range, gather up to `cap` composite keys.

    keys: (M,) sorted int64 (INF padded). lo/hi: (B,).
    Returns (k (B, cap), valid (B, cap), n_missed (B,)).
    """
    m = keys.shape[0]
    start = searchsorted(keys, lo, impl)
    end = searchsorted(keys, hi, impl)
    idx = start[:, None] + jnp.arange(cap)[None]
    k = keys[jnp.minimum(idx, m - 1)]
    valid = idx < end[:, None]
    missed = jnp.maximum(end - start - cap, 0)
    return k, valid, missed


def apply_residual(k: jnp.ndarray, valid: jnp.ndarray,
                   flt_vals: jnp.ndarray, flt_mask: tuple[bool, bool, bool],
                   eq_positions=()) -> jnp.ndarray:
    """Server-side filter: keep entries whose unpacked positions match."""
    t = unpack3(k)  # 3 x (B, cap)
    for pos in range(3):
        if flt_mask[pos]:
            valid = valid & (t[pos] == flt_vals[:, pos][:, None])
    for a, b in eq_positions:
        valid = valid & (t[a] == t[b])
    return valid


def probe(plan: PatternPlan, keys: jnp.ndarray, table: jnp.ndarray,
          row_valid: jnp.ndarray, cap: int, impl: str = "jnp"):
    """The MAPSIN inner loop body: dynamic GET for each input mapping.

    Returns (matched keys (B, cap), match mask, missed counts (B,)).
    With impl="pallas"/"pallas_interpret" the whole GET — rank-find, range
    gather, residual filter, slot placement — runs as ONE fused kernel
    (kernels/probe_gather.py); the jnp path below is the validated
    reference (match keys differ only at masked slots: the kernel writes
    0 where the reference leaves clamped-gather garbage).
    """
    lo, hi = probe_ranges(plan, table)
    lo = jnp.where(row_valid, lo, 0)
    hi = jnp.where(row_valid, hi, 0)   # invalid rows probe an empty range
    flt, msk = residual_values(plan, table)
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import ops
        return ops.probe_gather(keys, lo, hi, flt, cap=cap, flt_mask=msk,
                                eq_positions=plan.eq_positions,
                                interpret=(impl == "pallas_interpret"))
    k, valid, missed = gather_range(keys, lo, hi, cap, impl)
    valid = apply_residual(k, valid, flt, msk, plan.eq_positions)
    return k, valid, missed


def merge_bindings(bindings: Bindings, plan: PatternPlan, k: jnp.ndarray,
                   match: jnp.ndarray, missed: jnp.ndarray,
                   out_cap: int) -> Bindings:
    """Merge mu_n with compatible mappings (Alg. 1 lines 11-17).

    Instead of broadcasting the old table to (bcap, cap, n_vars) and
    compacting the full widened rows, only the ORIGIN index plus the <= 3
    newly bound columns are scattered; the surviving old columns are
    gathered once at the end — the intermediate shrinks from
    (bcap*cap, n_vars+new) to (bcap*cap, 1+new).
    """
    bcap, cap = match.shape
    t = unpack3(k)
    origin = jnp.broadcast_to(
        jnp.arange(bcap, dtype=jnp.int32)[:, None], (bcap, cap))
    cols = [origin] + [t[pos].astype(jnp.int32) for _, pos in plan.out_vars]
    rows = jnp.stack([c.reshape(-1) for c in cols], axis=1)
    valid = (match & bindings.valid[:, None]).reshape(-1)
    packed, vmask, dropped = compact(rows, valid, out_cap)
    table = bindings.table[packed[:, 0]]
    if plan.out_vars:
        table = jnp.concatenate([table, packed[:, 1:]], axis=1)
    table = jnp.where(vmask[:, None], table, 0)
    overflow = (bindings.overflow + dropped
                + jnp.sum(jnp.where(bindings.valid, missed, 0)).astype(jnp.int32))
    return Bindings(bindings.vars + plan.out_var_names, table, vmask, overflow)


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------


def scan_pattern(pattern, keys: jnp.ndarray, out_cap: int,
                 impl: str = "jnp", scratch: "Bindings | None" = None) -> Bindings:
    """First-pattern input phase: scan the (locally stored) index slice.

    Equivalent of the distributed HBase table scan that feeds the map phase.
    `scratch` (a zeroed Bindings of matching shape, typically donated by the
    jitted cascade in core/bgp.py) is consumed as the output buffers.
    """
    plan = make_plan(pattern, ())
    empty = jnp.zeros((1, 0), jnp.int32)
    lo, hi = probe_ranges(plan, empty)
    flt, msk = residual_values(plan, empty)
    within = (keys >= lo[0]) & (keys < hi[0])
    within = apply_residual(keys[None, :], within[None, :],
                            jnp.broadcast_to(flt, (1, 3)), msk,
                            plan.eq_positions)[0]
    t = unpack3(keys)
    cols = [t[pos][:, None] for _, pos in plan.out_vars]
    rows = (jnp.concatenate(cols, axis=-1) if cols
            else jnp.zeros((keys.shape[0], 0), jnp.int64)).astype(jnp.int32)
    buf = scratch.table if scratch is not None else None
    table, vmask, dropped = compact(rows, within, out_cap, buf=buf)
    overflow = dropped.astype(jnp.int32)
    if scratch is not None:
        vmask = vmask | scratch.valid          # zeros; consumes the buffer
        overflow = overflow + scratch.overflow
    return Bindings(plan.out_var_names, table, vmask, overflow)


def mapsin_step(bindings: Bindings, pattern, keys: jnp.ndarray,
                probe_cap: int, out_cap: int, impl: str = "jnp") -> Bindings:
    """One cascading MAPSIN iteration (Algorithm 1) on local data."""
    plan = make_plan(pattern, bindings.vars)
    k, match, missed = probe(plan, keys, bindings.table, bindings.valid,
                             probe_cap, impl)
    return merge_bindings(bindings, plan, k, match, missed, out_cap)


def multiway_step(bindings: Bindings, patterns: Sequence, keys: jnp.ndarray,
                  row_cap: int, out_cap: int, impl: str = "jnp") -> Bindings:
    """Optimized multiway star join (Algorithm 3): ONE row-GET per input
    mapping answers all patterns sharing the join variable on the primary
    position; per-pattern predicate filters are applied to the fetched row.
    """
    plans = [make_plan(p, bindings.vars) for p in patterns]
    p0 = plans[0]
    assert all(pl.index == p0.index and len(pl.prefix) >= 1 and
               pl.prefix[0] == p0.prefix[0] for pl in plans), \
        "multiway requires a shared primary-position join variable"
    lo, hi = row_range(p0, bindings.table)
    lo = jnp.where(bindings.valid, lo, 0)
    hi = jnp.where(bindings.valid, hi, 0)
    k, in_row, missed = gather_range(keys, lo, hi, row_cap, impl)

    out = bindings
    origin = jnp.arange(bindings.capacity, dtype=jnp.int32)[:, None]
    cur_origin = origin[:, 0]                     # (cap,) row -> probe index
    for plan in plans:
        flt, msk = residual_values(plan, bindings.table)
        # secondary/tertiary prefix components become residual filters on
        # the fetched row (they were part of the GET key in the 2-way case)
        extra_vals = jnp.zeros((bindings.capacity, 3), jnp.int64)
        extra_msk = [False, False, False]
        for pos, sc in enumerate(plan.prefix[1:], start=1):
            from repro.core.plan import _resolve
            extra_vals = extra_vals.at[:, pos].set(_resolve(sc, bindings.table))
            extra_msk[pos] = True
        match = apply_residual(k, in_row, flt, msk, plan.eq_positions)
        match = apply_residual(k, match, extra_vals, tuple(extra_msk))
        # expand current out rows against this pattern's matches
        km = k[cur_origin]                         # (out_cap, row_cap)
        mm = match[cur_origin] & out.valid[:, None]
        t = unpack3(km)
        old = jnp.broadcast_to(out.table[:, None, :],
                               (out.capacity, row_cap, len(out.vars)))
        new_cols = [t[pos][..., None] for _, pos in plan.out_vars]
        rows = jnp.concatenate([old] + new_cols, -1) if new_cols else old
        ori = jnp.broadcast_to(cur_origin[:, None], (out.capacity, row_cap))
        rows = jnp.concatenate([rows, ori[..., None]], -1)
        table, vmask, dropped = compact(
            rows.reshape(out.capacity * row_cap, -1).astype(jnp.int32),
            mm.reshape(-1), out_cap)
        cur_origin = table[:, -1]
        out = Bindings(out.vars + plan.out_var_names, table[:, :-1], vmask,
                       out.overflow + dropped)
    overflow = out.overflow + jnp.sum(
        jnp.where(bindings.valid, missed, 0)).astype(jnp.int32)
    return Bindings(out.vars, out.table, out.valid, overflow)
