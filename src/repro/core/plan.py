"""Pattern -> probe plan compilation (the paper's Table 3 index selection).

A ``PatternPlan`` is the static recipe for answering one triple pattern given
a multiset of solution mappings: which index (T_spo / T_ops), the bound key
prefix (-> one binary-search range = HBase GET/SCAN), residual equality
filters (-> server-side predicate push-down), and which index-order
positions feed which output variables.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp

from repro.core.rdf import BITS, INF_KEY, Pattern, is_var, pack3
from repro.core.triple_store import OPS, SPO

# value sources for prefix/filters: ("const", id) or ("var", binding column)
Source = tuple[str, int]


@dataclasses.dataclass(frozen=True)
class PatternPlan:
    pattern: Pattern
    index: int                         # SPO or OPS
    prefix: tuple[Source, ...]         # length 0..3, in index order
    residual: tuple[tuple[int, Source], ...]  # (index-order position, source)
    out_vars: tuple[tuple[str, int], ...]     # (var name, index-order position)
    eq_positions: tuple[tuple[int, int], ...]  # intra-pattern var repeats
    is_scan: bool                      # no bound prefix -> full SCAN

    @property
    def out_var_names(self) -> tuple[str, ...]:
        return tuple(v for v, _ in self.out_vars)


def _index_order(index: int, pattern: Pattern):
    s, p, o = pattern.terms
    return (s, p, o) if index == SPO else (o, p, s)


def make_plan(pattern: Pattern, domain: Sequence[str]) -> PatternPlan:
    """domain: variable names already bound (binding table columns)."""
    dom = {v: i for i, v in enumerate(domain)}

    def src(term) -> Source | None:
        if not is_var(term):
            return ("const", int(term))
        if term in dom:
            return ("var", dom[term])
        return None

    s_b, o_b = src(pattern.s), src(pattern.o)
    index = SPO if s_b is not None or o_b is None else OPS
    terms = _index_order(index, pattern)
    sources = [src(t) for t in terms]

    prefix: list[Source] = []
    for sc in sources:
        if sc is None:
            break
        prefix.append(sc)
    residual = tuple((i, sc) for i, sc in enumerate(sources)
                     if sc is not None and i >= len(prefix))

    out_vars: list[tuple[str, int]] = []
    eq: list[tuple[int, int]] = []
    seen: dict[str, int] = {}
    for i, t in enumerate(terms):
        if is_var(t) and t not in dom:
            if t in seen:
                eq.append((seen[t], i))
            else:
                seen[t] = i
                out_vars.append((t, i))
    return PatternPlan(pattern, index, tuple(prefix), residual,
                       tuple(out_vars), tuple(eq), is_scan=len(prefix) == 0)


def _resolve(source: Source, table: jnp.ndarray) -> jnp.ndarray:
    """table: (B, nv) int32 -> (B,) int64 values."""
    kind, v = source
    if kind == "const":
        return jnp.full((table.shape[0],), v, jnp.int64)
    return table[:, v].astype(jnp.int64)


def next_prefix(lo: jnp.ndarray, shift: int) -> jnp.ndarray:
    """Exclusive upper bound of the composite-key range whose bound prefix
    ends `shift` bits above the bottom: ``lo + (1 << shift)``, saturated at
    INF_KEY.

    The former ``pack3(v, w + 1, 0)`` formulation is wrong at the field
    boundary: with ``w == MAX_ID`` the incremented field spills into the
    field above, and ``pack3``'s ``|`` cannot carry — the stray bit lands on
    an already-set bit, yielding ``hi <= lo`` (a silently empty range); with
    a *leading* field at MAX_ID the shift wraps int64 negative. Plain
    integer addition carries correctly across fields; the single remaining
    overflow (every bound field at MAX_ID, so ``lo + (1 << shift)`` = 2^63)
    saturates to INF_KEY, which as an *exclusive* bound still covers every
    storable key: real keys are < INF_KEY — it is the padding sentinel, and
    the one colliding triple (MAX_ID, MAX_ID, MAX_ID) is rejected by
    build_store (the Dictionary reserves id MAX_ID).
    """
    hi = lo + (jnp.int64(1) << shift)
    return jnp.where(hi < lo, jnp.int64(INF_KEY), hi)


def probe_ranges(plan: PatternPlan, table: jnp.ndarray):
    """Compute per-binding [lo, hi) composite-key ranges. table: (B, nv)."""
    b = table.shape[0]
    zero = jnp.zeros((b,), jnp.int64)
    vals = [_resolve(s, table) for s in plan.prefix]
    plen = len(vals)
    if plen == 0:
        lo = zero
        hi = jnp.full((b,), INF_KEY, jnp.int64)
    elif plen == 1:
        lo = pack3(vals[0], zero, zero)
        hi = next_prefix(lo, 2 * BITS)
    elif plen == 2:
        lo = pack3(vals[0], vals[1], zero)
        hi = next_prefix(lo, BITS)
    else:
        lo = pack3(vals[0], vals[1], vals[2])
        hi = next_prefix(lo, 0)
    return lo, hi


def residual_values(plan: PatternPlan, table: jnp.ndarray):
    """(B, 3) filter values + (3,) bool mask over index-order positions."""
    b = table.shape[0]
    vals = jnp.zeros((b, 3), jnp.int64)
    mask = [False, False, False]
    for pos, sc in plan.residual:
        vals = vals.at[:, pos].set(_resolve(sc, table))
        mask[pos] = True
    return vals, tuple(mask)


def row_range(plan: PatternPlan, table: jnp.ndarray):
    """Whole-row range on the primary key only (multiway single-GET,
    paper Alg. 3): [pack(v, 0, 0), pack(v, 0, 0) + 2^42) — same
    boundary-safe arithmetic as probe_ranges (next_prefix), since
    ``pack3(v + 1, 0, 0)`` wraps negative at v == MAX_ID."""
    assert len(plan.prefix) >= 1
    v = _resolve(plan.prefix[0], table)
    zero = jnp.zeros_like(v)
    lo = pack3(v, zero, zero)
    return lo, next_prefix(lo, 2 * BITS)
