"""Reduce-side (repartition) join baseline — the paper's comparison point.

Per iteration: the pattern's full relation is scanned (map phase), then BOTH
the accumulated solution multiset and the relation are hash-partitioned by
join key across all shards (shuffle phase — full-relation network traffic),
then joined locally (reduce phase: sort-merge). This mirrors Pig's
reduce-side join that PigSPARQL uses in the paper's evaluation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.distributed import repartition
from repro.core.mapsin import Bindings, compact, scan_pattern
from repro.core.plan import make_plan


def sort_merge_join(lt, lv, rt, rv, lkey_col: int, rkey_col: int,
                    extra_eq: list[tuple[int, int]], r_out_cols: list[int],
                    probe_cap: int, out_cap: int):
    """Local equi-join of two fixed-capacity row tables on one key column.

    Returns (table, valid, dropped) with columns = left cols + r_out_cols.
    """
    rkey = jnp.where(rv, rt[:, rkey_col], jnp.int32(2**31 - 1))
    order = jnp.argsort(rkey)
    rks, rts, rvs = rkey[order], rt[order], rv[order]
    lkey = lt[:, lkey_col]
    lo = jnp.searchsorted(rks, lkey, side="left")
    hi = jnp.searchsorted(rks, lkey, side="right")
    idx = lo[:, None] + jnp.arange(probe_cap)[None]
    m = rks.shape[0]
    take = jnp.minimum(idx, m - 1)
    match = (idx < hi[:, None]) & lv[:, None] & rvs[take]
    missed = jnp.maximum(hi - lo - probe_cap, 0)
    rrows = rts[take]                                    # (L, cap, nvr)
    for la, ra in extra_eq:
        match = match & (lt[:, la][:, None] == rrows[..., ra])
    lrows = jnp.broadcast_to(lt[:, None, :], (lt.shape[0], probe_cap, lt.shape[1]))
    cols = [lrows] + [rrows[..., c][..., None] for c in r_out_cols]
    rows = jnp.concatenate(cols, -1).reshape(lt.shape[0] * probe_cap, -1)
    table, vmask, dropped = compact(rows, match.reshape(-1), out_cap)
    dropped = dropped + jnp.sum(jnp.where(lv, missed, 0)).astype(jnp.int32)
    return table, vmask, dropped


def dist_reduce_step(bnd: Bindings, pattern, local_keys, scan_cap: int,
                     bucket_cap: int, probe_cap: int, out_cap: int,
                     axis: str, impl: str = "jnp") -> Bindings:
    """One reduce-side join iteration (shuffle both sides, join in 'reduce')."""
    plan = make_plan(pattern, bnd.vars)
    rel = scan_pattern(pattern, local_keys, scan_cap, impl)
    shared = [v for v in plan.pattern.variables if v in bnd.vars]
    assert shared, "reduce-side join requires a shared variable"
    jvar = shared[0]
    lcol = bnd.vars.index(jvar)
    rcol = rel.vars.index(jvar)
    extra_eq = [(bnd.vars.index(v), rel.vars.index(v)) for v in shared[1:]]
    r_out = [i for i, v in enumerate(rel.vars) if v not in bnd.vars]
    # ---- shuffle phase: both relations cross the network ----
    lt, lv, dl = repartition(bnd.table, bnd.valid, bnd.table[:, lcol],
                             bucket_cap, axis)
    rt, rv, dr = repartition(rel.table, rel.valid, rel.table[:, rcol],
                             bucket_cap, axis)
    # ---- reduce phase: local sort-merge join ----
    table, vmask, dropped = sort_merge_join(
        lt, lv, rt, rv, lcol, rcol, extra_eq, r_out, probe_cap, out_cap)
    new_vars = bnd.vars + tuple(v for v in rel.vars if v not in bnd.vars)
    overflow = (bnd.overflow + rel.overflow + dl + dr + dropped)
    return Bindings(new_vars, table, vmask, overflow)


def local_reduce_step(bnd: Bindings, pattern, keys, scan_cap: int,
                      probe_cap: int, out_cap: int, impl: str = "jnp") -> Bindings:
    """Single-shard reduce-side join (no shuffle — functional baseline)."""
    plan = make_plan(pattern, bnd.vars)
    rel = scan_pattern(pattern, keys, scan_cap, impl)
    shared = [v for v in plan.pattern.variables if v in bnd.vars]
    assert shared, "reduce-side join requires a shared variable"
    jvar = shared[0]
    lcol = bnd.vars.index(jvar)
    rcol = rel.vars.index(jvar)
    extra_eq = [(bnd.vars.index(v), rel.vars.index(v)) for v in shared[1:]]
    r_out = [i for i, v in enumerate(rel.vars) if v not in bnd.vars]
    table, vmask, dropped = sort_merge_join(
        bnd.table, bnd.valid, rel.table, rel.valid, lcol, rcol, extra_eq,
        r_out, probe_cap, out_cap)
    new_vars = bnd.vars + tuple(v for v in rel.vars if v not in bnd.vars)
    return Bindings(new_vars, table, vmask, bnd.overflow + rel.overflow + dropped)
