"""Cost-based query planner: ``LogicalPlan`` -> ``PhysicalPlan`` IR
(DESIGN.md §6).

The paper's §7 defers "statistics-based selectivity estimation" and
per-step join-operator choice to future work; the sorted composite-key
store makes both free here. A compiled ``PhysicalPlan`` is the single
artifact every executor consumes (``execute_local``, ``execute_sharded``,
``ServeEngine``): each step carries

  * its **operator** — ``scan | mapsin | multiway | reduce_side`` —
    chosen per join (not per query): ``multiway`` by the star-grouping
    rule, ``reduce_side`` as the fallback when the measured probe
    fan-out would blow the cap budget or the pattern has no usable index
    prefix (a residual-only join, which an index GET cannot answer
    exactly under a finite probe cap);
  * its **capacities** (``Caps``) as static compile-time constants —
    subsuming the three out-of-band tuning mechanisms that used to run
    beside the planner (``tune_a2a_bucket_cap``, per-step answer caps,
    ``ServeEngine._maybe_tune``) and the shared ``{2^k, 3*2^(k-1)}``
    quantization grid (``quantize_cap``);
  * a **cost estimate** from exact pattern cardinalities plus the
    group-fanout statistics of the sorted index (rows per distinct
    bound-prefix value) — the join order is chosen by cost-based search
    (exhaustive left-deep for <= 6 patterns, greedy beyond) instead of
    pure variable counting.

``explain(plan)`` renders the chosen order, operators, caps, and cost
per step; with a ``stats`` list from an instrumented run it also shows
the ACTUAL row counts and per-step overflow (surfaced truncation).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Sequence

import numpy as np

from repro.core.plan import make_plan
from repro.core.rdf import BITS, INF_KEY, Pattern, is_var
from repro.core.triple_store import TripleStore

# operator sets: the full planner vocabulary, and the subset the serving
# engine's seeded-constant template cascade can express (reduce_side
# re-scans relations with an empty domain, which a template cannot seed)
ALL_OPERATORS = ("scan", "mapsin", "multiway", "reduce_side")
ENGINE_OPERATORS = ("scan", "mapsin", "multiway")


@dataclasses.dataclass(frozen=True)
class Caps:
    """Static capacity budget — input to the planner, embedded per step.

    These used to live on ``ExecConfig``; they are compile-time shape
    constants, not runtime knobs, so they now belong to the plan."""
    scan_cap: int = 1 << 14      # relation scan capacity (per shard)
    probe_cap: int = 8           # matches per GET (per mapping); also the
                                 # a2a answer-leg capacity
    row_cap: int = 32            # row width for multiway single-GET
    out_cap: int = 1 << 14       # solution multiset capacity (per shard)
    bucket_cap: int = 1 << 12    # reduce-side shuffle bucket capacity
    a2a_bucket_cap: int = 0      # per-destination probe bucket capacity for
                                 # routing="a2a"; 0 = embed the measured
                                 # probe->region fan-out at compile time


def quantize_cap(cap: int) -> int:
    """Round a capacity UP onto the ``{2^k, 3*2^(k-1)}`` grid (8, 12, 16,
    24, 32, 48, ...). Caps are compile-time constants, so free-form values
    would compile a cascade per distinct size; two sizes per octave bounds
    compile diversity at < 50% capacity overshoot (consecutive grid points
    are at most a 3/2 ratio apart). The one shared copy — the planner, the
    serving engine's batch-cap summing, and every test use this helper."""
    if cap <= 8:
        return 8
    k = 1 << (cap - 1).bit_length()            # next pow2 >= cap
    return (3 * k) // 4 if cap <= (3 * k) // 4 else k


def next_cap(cap: int) -> int:
    """The escalation successor of a capacity: the power of two STRICTLY
    above `cap` (floored at the grid minimum 8). Strictly increasing from
    any start, so an escalation chain never repeats a cap, and it lands
    back on the ``quantize_cap`` grid from either family of grid points:
    ``2^k -> 2^(k+1)`` and ``3*2^(k-1) -> 2^(k+1)`` (12 -> 16, 24 -> 32,
    48 -> 64) — geometric growth, at most two escalations per octave of
    actual demand."""
    return max(1 << int(cap).bit_length(), 8)


def escalate_caps(caps: Caps) -> Caps:
    """One overflow-escalation move: every truncating capacity advances to
    its ``next_cap`` (the serving engine re-plans and re-executes an
    overflowed query at the escalated budget; see DESIGN.md §7). All four
    row budgets move together — the overflow counter is cumulative across
    steps, so the escalation cannot tell a probe-cap drop from an out-cap
    drop, and growing only one would stall the chain when the other is the
    binding constraint. ``a2a_bucket_cap`` resets to 0 so the planner
    re-embeds the measured a2a capacities at the new budget."""
    return dataclasses.replace(
        caps, scan_cap=next_cap(caps.scan_cap),
        probe_cap=next_cap(caps.probe_cap), row_cap=next_cap(caps.row_cap),
        out_cap=next_cap(caps.out_cap), a2a_bucket_cap=0)


@dataclasses.dataclass(frozen=True)
class LogicalPlan:
    """What to answer: a conjunctive BGP, order-free."""
    patterns: tuple[Pattern, ...]


@dataclasses.dataclass(frozen=True)
class PlanStep:
    """One physical operator application with its static capacities."""
    kind: str                    # scan | mapsin | multiway | reduce_side
    patterns: tuple[Pattern, ...]
    caps: Caps
    est_in: int = 0              # estimated input mappings
    est_out: int = 0             # estimated output mappings
    est_fanout_max: int = 0      # estimated max matches per probe


@dataclasses.dataclass(frozen=True)
class PhysicalPlan:
    """The executable IR: ordered steps, each with operator + caps."""
    steps: tuple[PlanStep, ...]
    var_order: tuple[str, ...]   # final binding-column order
    cost: float                  # estimated total rows touched
    ordering: str                # cost | heuristic | given
    route_shards: int = 10       # hypothetical cluster for routed-traffic
                                 # measurement (paper's 10-node setup)

    @property
    def patterns(self) -> tuple[Pattern, ...]:
        return tuple(p for st in self.steps for p in st.patterns)


# ---------------------------------------------------------------------------
# Statistics (exact, from the sorted composite-key store; host-side, memoized)
# ---------------------------------------------------------------------------


def _host_keys(store: TripleStore, index: int) -> np.ndarray:
    """Host-side copy of one flattened index (one device->host transfer)."""
    ck = ("np_keys", index)
    if ck not in store.plan_cache:
        store.plan_cache[ck] = np.asarray(store.flat_keys(index))
    return store.plan_cache[ck]


def _host_fields(store: TripleStore, index: int):
    """Unpacked (pos0, pos1, pos2) int64 fields of the real (non-padding)
    keys of one index, in index order."""
    ck = ("np_fields", index)
    if ck not in store.plan_cache:
        keys = _host_keys(store, index)
        keys = keys[keys < INF_KEY]
        mask = np.int64((1 << BITS) - 1)
        store.plan_cache[ck] = ((keys >> (2 * BITS)) & mask,
                                (keys >> BITS) & mask, keys & mask)
    return store.plan_cache[ck]


def pattern_cardinality(store: TripleStore, pat: Pattern) -> int:
    """Exact result count for a pattern's constant key prefix — one binary
    search pair against the store index. This is the statistics-based
    selectivity the paper's §7 lists as future work; the sorted
    composite-key store makes it free. Memoized per store (planning stays
    off the timed path when the same query re-executes)."""
    ck = ("card", pat)
    if ck in store.plan_cache:
        return store.plan_cache[ck]
    plan = make_plan(pat, ())
    if not plan.prefix:
        n = store.n_triples
    else:
        import jax.numpy as jnp
        from repro.core.plan import probe_ranges
        empty = jnp.zeros((1, 0), jnp.int32)
        lo, hi = probe_ranges(plan, empty)
        keys = _host_keys(store, plan.index)
        n = int(np.searchsorted(keys, np.asarray(hi)[0])
                - np.searchsorted(keys, np.asarray(lo)[0]))
    store.plan_cache[ck] = n
    return n


def relation_stats(store: TripleStore, pat: Pattern,
                   domain: Sequence[str]) -> tuple[int, int, int]:
    """(rows, groups, max_group) of the pattern's relation under `domain`.

    ``rows``  — exact cardinality with EVERY constant applied (prefix and
                residual positions alike — unlike pattern_cardinality,
                which only sees the contiguous key prefix);
    ``groups``/``max_group`` — the relation grouped by the index-order
                positions a probe would bind from the domain: the average
                group ``rows/groups`` is the expected matches per probe
                (containment assumption) and ``max_group`` the worst-case
                probe fan-out (what sizes probe caps).

    One O(N) host pass per distinct (constants, var-positions) signature,
    memoized in the store's plan cache."""
    plan = make_plan(pat, domain)
    consts = tuple(sorted(
        (pos, v) for pos, (kind, v) in
        list(enumerate(plan.prefix)) + list(plan.residual)
        if kind == "const"))
    varpos = tuple(sorted(
        pos for pos, (kind, _) in
        list(enumerate(plan.prefix)) + list(plan.residual) if kind == "var"))
    ck = ("relstats", plan.index, consts, varpos)
    if ck in store.plan_cache:
        return store.plan_cache[ck]
    fields = _host_fields(store, plan.index)
    mask = np.ones(fields[0].shape, bool)
    for pos, v in consts:
        mask = mask & (fields[pos] == v)
    rows = int(mask.sum())
    if not varpos or rows == 0:
        out = (rows, 1 if rows else 0, rows)
    else:
        combo = np.zeros(rows, np.int64)
        for pos in varpos:
            combo = (combo << BITS) | fields[pos][mask]
        counts = np.unique(combo, return_counts=True)[1]
        out = (rows, int(len(counts)), int(counts.max()))
    store.plan_cache[ck] = out
    return out


# ---------------------------------------------------------------------------
# Join ordering: the legacy heuristic and the cost-based search
# ---------------------------------------------------------------------------


def order_patterns(patterns: Sequence[Pattern], reorder: bool = True,
                   store: TripleStore | None = None):
    """Variable-counting heuristic (paper §4.2): most selective first, then
    greedily prefer patterns connected to the bound domain. With a store,
    ties break on measured prefix-range cardinality. Kept as the baseline
    the cost-based search is benchmarked against (and the fallback when no
    store is available to supply statistics)."""
    pats = list(patterns)
    if not reorder:
        return pats

    def rank(p: Pattern):
        base = p.selectivity_rank()
        if store is not None:
            return base + (pattern_cardinality(store, p),)
        return base

    pats_sorted = sorted(pats, key=rank)
    out = [pats_sorted.pop(0)]
    domain = set(out[0].variables)
    while pats_sorted:
        connected = [p for p in pats_sorted if set(p.variables) & domain]
        nxt = min(connected or pats_sorted, key=rank)
        pats_sorted.remove(nxt)
        out.append(nxt)
        domain |= set(nxt.variables)
    return out


def _join_selectivity(store: TripleStore, pat: Pattern,
                      domain: Sequence[str]) -> tuple[float, int, int]:
    """(avg matches per probe, relation rows, max probe fan-out) of `pat`
    joined against `domain`: rows/groups under the containment
    assumption; a pattern sharing no domain variable degrades to the
    full relation (cross product), so cost search avoids cartesians
    without a special case. The ONE estimator — the order search and the
    per-step est_in/est_out annotations must agree."""
    rows, groups, mx = relation_stats(store, pat, domain)
    bound = set(pat.variables) & set(domain)
    avg = rows / groups if (bound and groups) else float(rows)
    return avg, rows, mx


def _order_cost(store: TripleStore, order: Sequence[Pattern]) -> float:
    """Estimated rows touched by a left-deep execution of `order`: scan
    rows + per-join (probes issued + rows produced), with expected
    matches per probe from _join_selectivity."""
    rows0, _, _ = relation_stats(store, order[0], ())
    est = float(rows0)
    cost = est
    domain = list(order[0].variables)
    for pat in order[1:]:
        avg, _, _ = _join_selectivity(store, pat, domain)
        out = est * avg
        cost += est + out
        est = out
        for v in pat.variables:
            if v not in domain:
                domain.append(v)
    return cost


_EXHAUSTIVE_LIMIT = 6    # <= 6 patterns: all left-deep orders (<= 720)


def cost_order(store: TripleStore, patterns: Sequence[Pattern]
               ) -> tuple[list[Pattern], float]:
    """Cost-based join order: exhaustive left-deep search for small BGPs,
    greedy (min incremental cost among connected candidates) beyond.
    Deterministic: cost ties break on the original pattern order."""
    pats = list(patterns)
    if len(pats) <= 1:
        c = (float(relation_stats(store, pats[0], ())[0]) if pats else 0.0)
        return pats, c
    if len(pats) <= _EXHAUSTIVE_LIMIT:
        best_key, best = None, None
        for perm in itertools.permutations(range(len(pats))):
            order = [pats[i] for i in perm]
            key = (_order_cost(store, order), perm)
            if best_key is None or key < best_key:
                best_key, best = key, order
        return best, best_key[0]
    # greedy: cheapest seed, then min incremental cost among connected
    remaining = list(range(len(pats)))
    first = min(remaining,
                key=lambda i: (relation_stats(store, pats[i], ())[0], i))
    order = [pats[first]]
    remaining.remove(first)
    domain = list(pats[first].variables)
    est = float(relation_stats(store, pats[first], ())[0])
    cost = est
    while remaining:
        def incr(i):
            avg, _, _ = _join_selectivity(store, pats[i], domain)
            return est + est * avg
        connected = [i for i in remaining
                     if set(pats[i].variables) & set(domain)]
        nxt = min(connected or remaining, key=lambda i: (incr(i), i))
        avg, _, _ = _join_selectivity(store, pats[nxt], domain)
        cost += est + est * avg
        est = est * avg
        order.append(pats[nxt])
        remaining.remove(nxt)
        for v in pats[nxt].variables:
            if v not in domain:
                domain.append(v)
    return order, cost


# ---------------------------------------------------------------------------
# Operator selection + step construction
# ---------------------------------------------------------------------------


def _group_multiway(ordered: Sequence[Pattern], multiway: bool):
    """Star-grouping rule (paper Alg. 2/3): consecutive patterns sharing
    the primary-position join variable on the same index, producing only
    fresh variables, collapse into one multiway row-GET."""
    groups: list[tuple[str, tuple[Pattern, ...]]] = [("scan", (ordered[0],))]
    domain: list[str] = list(ordered[0].variables)
    i = 1
    while i < len(ordered):
        group = [ordered[i]]
        if multiway:
            plan_i = make_plan(ordered[i], domain)
            new_vars = set(plan_i.out_var_names)
            j = i + 1
            while j < len(ordered) and len(plan_i.prefix) >= 1:
                cand = make_plan(ordered[j], domain)
                same_row = (cand.index == plan_i.index and
                            len(cand.prefix) >= 1 and
                            cand.prefix[0] == plan_i.prefix[0])
                fresh = not (set(cand.out_var_names) & new_vars)
                uses_new = bool(set(ordered[j].variables) & new_vars)
                if not (same_row and fresh and not uses_new):
                    break
                group.append(ordered[j])
                new_vars |= set(cand.out_var_names)
                j += 1
        kind = "multiway" if len(group) > 1 else "mapsin"
        groups.append((kind, tuple(group)))
        for g in group:
            for v in g.variables:
                if v not in domain:
                    domain.append(v)
        i += len(group)
    return groups


def _step_out_vars(kind: str, patterns: tuple[Pattern, ...],
                   domain: list[str]) -> list[str]:
    """New binding columns a step appends, in the operator's own order
    (reduce_side scans its relation with an EMPTY domain, so its column
    order comes from the empty-domain plan, not the probe plan)."""
    out: list[str] = []
    seen = set(domain)
    for pat in patterns:
        if kind == "reduce_side":
            names = make_plan(pat, ()).out_var_names
        else:
            names = make_plan(pat, tuple(domain) + tuple(out)).out_var_names
        for v in names:
            if v not in seen:
                seen.add(v)
                out.append(v)
    return out


def compile_plan(store: TripleStore | None, patterns, caps: Caps = Caps(),
                 mode: str = "mapsin", ordering: str = "cost",
                 multiway: bool = True, reorder: bool = True,
                 operators: tuple[str, ...] = ALL_OPERATORS,
                 routing: str = "broadcast", num_shards: int = 0,
                 route_shards: int = 10) -> PhysicalPlan:
    """The LogicalPlan -> PhysicalPlan compiler.

    `patterns` may be a LogicalPlan or a Pattern sequence. `ordering` is
    "cost" (default; falls back to "heuristic" without a store) or
    "heuristic" (the legacy variable-counting baseline); `reorder=False`
    keeps the given order. `mode="reduce"` forces every join step onto
    the reduce-side operator (the paper's comparison baseline);
    otherwise operators are chosen per step, restricted to `operators`
    (the serving engine passes ENGINE_OPERATORS — its seeded template
    cascade cannot express reduce_side).

    With `num_shards > 0` and `routing="a2a"` and `caps.a2a_bucket_cap
    == 0`, the per-step a2a capacities are EMBEDDED from measurement:
    one instrumented run of this plan (cached per plan on the store)
    sizes the per-destination probe buckets to the max per-region load
    any step delivers and the answer legs to the measured max range
    length per step — subsuming tune_a2a_bucket_cap /
    tuned_step_answer_caps / ServeEngine._maybe_tune.
    """
    if isinstance(patterns, LogicalPlan):
        patterns = patterns.patterns
    patterns = tuple(patterns)
    if not patterns:
        raise ValueError("empty pattern list")
    if mode == "reduce" and "reduce_side" not in operators:
        raise ValueError("mode='reduce' needs the reduce_side operator — "
                         "it cannot be expressed under this operator set")
    ck = None
    if store is not None:
        ck = ("pplan", patterns, caps, mode, ordering, multiway, reorder,
              operators, routing, num_shards, route_shards)
        hit = store.plan_cache.get(ck)
        if hit is not None:
            return hit
    if not reorder:
        ordered, chosen = list(patterns), "given"
        cost = (_order_cost(store, ordered) if store is not None
                else float("nan"))
    elif ordering == "cost" and store is not None:
        ordered, cost, chosen = *cost_order(store, patterns), "cost"
    else:
        ordered = order_patterns(patterns, True, store)
        cost = (_order_cost(store, ordered) if store is not None
                else float("nan"))
        chosen = "heuristic"

    groups = _group_multiway(ordered, multiway)
    steps: list[PlanStep] = []
    domain: list[str] = []
    var_order: list[str] = []
    est = 0.0
    for kind, pats in groups:
        est_in = est
        fan_max = 0
        if kind == "scan":
            est = (float(relation_stats(store, pats[0], ())[0])
                   if store is not None else 0.0)
        else:
            if mode == "reduce":
                kind = "reduce_side"
            for pat in pats:
                if store is None:
                    continue
                avg, _, mx = _join_selectivity(store, pat, domain)
                est = est * avg
                fan_max = max(fan_max, mx)
            if (kind == "mapsin" and mode != "reduce"
                    and "reduce_side" in operators and store is not None):
                kind = _maybe_reduce_side(store, pats[0], domain, caps)
        scaps = caps
        if kind == "reduce_side" and mode != "reduce" and store is not None:
            # right-size the sort-merge per-row match budget: the merge
            # windows on the SINGLE join-key column (local_reduce_step's
            # shared[0]; extra shared vars filter AFTER the window), so
            # the budget must cover the relation's max group per join-key
            # VALUE — fan_max (grouped by every bound position) can be
            # smaller and would still truncate
            shared = [v for v in pats[0].variables if v in domain]
            fan_key = (relation_stats(store, pats[0], (shared[0],))[2]
                       if shared else fan_max)
            scaps = dataclasses.replace(
                caps, probe_cap=max(caps.probe_cap,
                                    quantize_cap(min(max(fan_key, 1),
                                                     caps.out_cap))))
        clamp = lambda x: int(min(x, 1e18))
        steps.append(PlanStep(kind, pats, scaps, clamp(est_in), clamp(est),
                              fan_max))
        new = _step_out_vars(kind, pats, domain)
        domain.extend(v for p in pats for v in p.variables
                      if v not in domain)
        var_order.extend(new)
    plan = PhysicalPlan(tuple(steps), tuple(var_order),
                        float(cost) if cost == cost else 0.0, chosen,
                        route_shards)
    # a positive a2a_bucket_cap is an explicit pin (the documented
    # drop-free override) — it skips the measurement pass entirely
    if (num_shards > 0 and routing == "a2a" and mode != "reduce"
            and caps.a2a_bucket_cap == 0 and store is not None):
        plan = embed_a2a_caps(store, plan, caps, num_shards)
    if ck is not None:
        store.plan_cache[ck] = plan
    return plan


def _maybe_reduce_side(store: TripleStore, pat: Pattern, domain: list[str],
                       caps: Caps) -> str:
    """Per-step operator fallback (Naacke et al.'s hybrid selection): keep
    ``mapsin`` unless (a) the probe plan has NO bound key prefix — a
    residual-only join, where the index GET degenerates to a full-range
    scan truncated at probe_cap — or (b) the relation's measured max
    probe fan-out blows the probe-cap budget while the relation still
    fits a reduce-side scan. Both require a shared variable (sort-merge
    needs a join key); a genuine cartesian stays on mapsin."""
    plan = make_plan(pat, domain)
    shared = [v for v in pat.variables if v in domain]
    if not shared:
        return "mapsin"
    if not plan.prefix:
        return "reduce_side"
    rows, _, mx = relation_stats(store, pat, domain)
    if mx > caps.probe_cap and rows <= caps.scan_cap:
        return "reduce_side"
    return "mapsin"


# ---------------------------------------------------------------------------
# Measured a2a capacity embedding (subsumes the three tuning mechanisms)
# ---------------------------------------------------------------------------


def embed_a2a_caps(store: TripleStore, plan: PhysicalPlan,
                   caps: Caps | None, num_shards: int) -> PhysicalPlan:
    """Embed measured a2a capacities into every join step of `plan`.

    One instrumented run of the plan (host-side, cached per (plan, S) on
    the store) measures, per join step, the max per-region probe load —
    which sizes the per-destination a2a probe buckets — and the max
    range-entry count any probe covers — which sizes the a2a answer
    return leg (min'd with the configured probe/row caps: never looser
    than the budget). ``out_cap`` stays the drop-free fallback when
    nothing was measurable (a single-step scan never probes) or when the
    tuning run OVERFLOWED: the sharded run keeps out_cap rows PER SHARD,
    so a truncated single-store measurement would under-size the buckets
    and drop probes. With ``caps=None`` the drop-free bound is read OFF
    the plan's own step caps (a pre-compiled plan arriving via
    execute_sharded carries its budget in its steps — clamping to some
    unrelated default would under-size the buckets)."""
    ck = ("a2a_embed", plan, num_shards)
    hit = store.plan_cache.get(ck)
    if hit is not None:
        return hit
    if caps is None:
        # the structural drop-free bound of THIS plan: a shard never
        # routes more probes per step than that step has input bindings
        out_caps = [st.caps.out_cap for st in plan.steps[1:]
                    if st.kind in ("mapsin", "multiway")]
        bound = max(out_caps) if out_caps else plan.steps[0].caps.out_cap
    else:
        bound = caps.out_cap
    from repro.core import bgp  # lazy: bgp imports this module at top level
    stats: list = []
    probe = dataclasses.replace(plan, route_shards=num_shards)
    bnd = bgp.execute_local(store, probe, "mapsin", bgp.ExecConfig(),
                            stats=stats)
    loads = [st["deliveries_max_region"] for st in stats
             if st["kind"] not in ("scan", "reduce_side")
             and "deliveries_max_region" in st]
    overflowed = int(np.asarray(bnd.overflow)) > 0
    if not loads or overflowed:
        bucket = bound
    else:
        bucket = min(max(max(loads), 8), bound)
    join_stats = [st for st in stats if st["kind"] != "scan"]
    steps = [plan.steps[0]]
    for st, stat in zip(plan.steps[1:], join_stats):
        scaps = dataclasses.replace(st.caps, a2a_bucket_cap=bucket)
        if not overflowed and st.kind in ("mapsin", "multiway"):
            measured = quantize_cap(max(stat.get("probe_len_max", 0), 1))
            if st.kind == "multiway":
                scaps = dataclasses.replace(
                    scaps, row_cap=min(measured, st.caps.row_cap))
            else:
                scaps = dataclasses.replace(
                    scaps, probe_cap=min(measured, st.caps.probe_cap))
        steps.append(dataclasses.replace(st, caps=scaps))
    out = dataclasses.replace(plan, steps=tuple(steps))
    store.plan_cache[ck] = out
    return out


# ---------------------------------------------------------------------------
# explain
# ---------------------------------------------------------------------------


def _fmt_term(t, decode: Callable | None) -> str:
    if is_var(t):
        return t
    if decode is not None:
        try:
            return f"<{decode(int(t))}>"
        except Exception:
            pass
    return f"<{int(t)}>"


def _fmt_pattern(p: Pattern, decode: Callable | None) -> str:
    return " ".join(_fmt_term(t, decode) for t in p.terms)


def explain(plan: PhysicalPlan, stats: list | None = None,
            decode: Callable | None = None) -> str:
    """Human-readable rendering of a PhysicalPlan: per step the operator,
    patterns, estimated in/out rows + max probe fan-out, and the embedded
    caps. With `stats` (the per-step dicts an instrumented execute_local
    appends) each step also shows ACTUAL output rows, the per-step
    overflow counter, and the estimated-vs-actual drift (`drift=xR`,
    actual/estimated output rows — the cost model's per-step error, so
    cardinality misestimates are visible without a trace viewer) —
    undersized caps are reported, never silent. `decode` (e.g.
    Dictionary.term) renders constant ids as terms."""
    lines = [f"PhysicalPlan: {len(plan.steps)} steps, "
             f"ordering={plan.ordering}, est_cost={plan.cost:.0f}, "
             f"vars=({', '.join(plan.var_order)})"]
    for i, st in enumerate(plan.steps):
        pats = " | ".join(_fmt_pattern(p, decode) for p in st.patterns)
        c = st.caps
        if st.kind == "scan":
            caps_s = f"out={c.out_cap}"
        elif st.kind == "reduce_side":
            caps_s = (f"scan={c.scan_cap} probe={c.probe_cap} "
                      f"out={c.out_cap} bucket={c.bucket_cap}")
        elif st.kind == "multiway":
            caps_s = f"row={c.row_cap} out={c.out_cap} a2a={c.a2a_bucket_cap}"
        else:
            caps_s = (f"probe={c.probe_cap} out={c.out_cap} "
                      f"a2a={c.a2a_bucket_cap}")
        est = (f"est_out={st.est_out}" if st.kind == "scan"
               else f"est_in={st.est_in} est_out={st.est_out} "
                    f"fanout_max={st.est_fanout_max}")
        line = f"  [{i}] {st.kind:<11s} {{{pats}}}  {est}  caps: {caps_s}"
        if stats is not None and i < len(stats):
            act = stats[i]["n_out"]
            drift = (act / st.est_out if st.est_out
                     else (float("inf") if act else 1.0))
            line += (f"  actual: rows={act} "
                     f"overflow={stats[i].get('overflow', 0)} "
                     f"drift=x{drift:.2f}")
            if "wall_s" in stats[i]:
                line += f" wall={stats[i]['wall_s'] * 1e3:.2f}ms"
        lines.append(line)
    if stats is not None:
        est_final = plan.steps[-1].est_out if plan.steps else 0
        act_final = stats[-1]["n_out"] if stats else 0
        lines.append(f"  est cost {plan.cost:.0f}; final rows "
                     f"est={est_final} actual={act_final}")
        total_ovf = sum(st.get("overflow", 0) for st in stats)
        if total_ovf:
            lines.append(f"  !! {total_ovf} rows dropped by capacity "
                         f"truncation — raise the reported caps")
    return "\n".join(lines)
