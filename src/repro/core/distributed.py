"""Distributed MAPSIN execution: shard_map + explicit collectives.

Traffic model (the faithful translation of the paper's network argument):
  MAPSIN step   — ship ONLY probe keys and ONLY matching tuples, two ways:
      routing="broadcast" — all_gather(probe keys) + psum_scatter(matches):
                  every shard sees every probe and answers the ones whose
                  range intersects its region. Pays O(S) on the key leg;
                  kept as the validated reference path.
      routing="a2a"       — point-to-point dispatch (DESIGN.md §2): each
                  probe record (lo/hi — the residual filters stay on the
                  origin shard, which applies them after the round trip)
                  is bucketed by the region(s) its range intersects (the
                  stored splits) and shipped with all_to_all only to those
                  shards; raw range entries ride a second all_to_all home,
                  keyed on the sender's bucket slots. This is the paper's
                  HBase region-server GET: O(B) probe bytes, independent
                  of the cluster size.
  reduce-side   — all_to_all(BOTH full relations)  (see reduce_side.py)

The store is range-sharded; a probe whose key range spans several shards
(fat rows, the `rdf:type` problem) is answered by every intersecting shard
and the per-shard match counts are offset-composed, so results concatenate
exactly once — the compound-rowkey fix without compound keys. Both routings
preserve that invariant: per-shard matches are packed in key order and
offsets compose in shard (= global key) order, so the two paths produce
bit-identical Bindings.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.mapsin import Bindings, apply_residual, compact, gather_range
from repro.core.plan import make_plan, probe_ranges, residual_values, row_range
from repro.core.rdf import unpack3
from repro.core.triple_store import range_intersects_region


def _axis_size(axis: str) -> int:
    return jax.lax.psum(1, axis)


def _my_region(shard_splits, axis: str):
    """This shard's (last-key-of-previous-shard, last-own-key] bounds from
    the stored region boundaries (triple_store splits arrays)."""
    if shard_splits is None:
        return None
    sp = jnp.asarray(shard_splits)
    me = jax.lax.axis_index(axis)
    return jnp.take(sp, me), jnp.take(sp, me + 1)


def bucket_rows(send: jnp.ndarray, cap: int, payload: Sequence[jnp.ndarray]):
    """Pack records into per-destination send buckets (the shared bucketing
    machinery behind `repartition` and the a2a probe dispatch).

    send: (n, S) bool — record i is addressed to destination s; a record may
    target several destinations (the fat-row fan-out) or none (invalid /
    masked rows). payload: arrays shaped (n,) or (n, k), scattered together.

    Returns (bufs, slot, dropped):
      bufs    — one (S, cap[, k]) buffer per payload array, records packed
                to the front of each destination bucket in row order;
      slot    — (n, S) int32, the in-bucket position each (record, dest)
                copy landed at, == cap for copies not shipped (dropped or
                not addressed) — the sender's receipt, used to claim
                answers that come back in bucket order;
      dropped — (n,) int32 count of addressed-but-dropped copies per record
                (bucket overflow; surfaced, never silent).
    """
    n, S = send.shape
    rank = jnp.cumsum(send.astype(jnp.int32), axis=0) - 1        # (n, S)
    keep = send & (rank < cap)
    slot = jnp.where(keep, rank, cap)                            # cap == spill
    dest = jnp.broadcast_to(jnp.arange(S)[None, :], (n, S))
    bufs = []
    for p in payload:
        extra = p.shape[1:]
        kmask = keep.reshape((n, S) + (1,) * len(extra))
        val = jnp.broadcast_to(p[:, None], (n, S) + extra)
        buf = jnp.zeros((S, cap + 1) + extra, p.dtype)
        buf = buf.at[dest, slot].set(
            jnp.where(kmask, val, jnp.zeros((), p.dtype)))
        bufs.append(buf[:, :cap])
    dropped = jnp.sum(send & ~keep, axis=1).astype(jnp.int32)
    return bufs, slot, dropped


def _a2a(x, axis: str):
    """Tiled all_to_all over leading (S * cap) blocks."""
    return jax.lax.all_to_all(x, axis, split_axis=0, concat_axis=0,
                              tiled=True)


_SALT = 0x9E3779B97F4A7C15 - (1 << 64)        # golden-ratio mix, as int64


def _leg_checksum(ans, cnt, miss, answerer):
    """Salted positional checksum of one shard's outgoing answer blocks.

    ans (S, cap, P) int64, cnt/miss (S, cap) int32 -> (S,) int64, one
    checksum per destination block. Position-sensitive (odd weights per
    slot, so swapped or shifted entries change the sum) and salted with
    the ANSWERER's shard id, so a zeroed block (dropped packets) can
    never reproduce the checksum of a legitimately empty answer — the
    origin recomputes with the salt of the shard that block POSITION
    belongs to. int64 wraparound is two's-complement on both sides, so
    the comparison stays exact."""
    s, cap, p = ans.shape
    w = (2 * jnp.arange(cap * p, dtype=jnp.int64) + 1).reshape(cap, p)
    wc = 2 * jnp.arange(cap, dtype=jnp.int64) + 1
    h = (jnp.sum(ans * w[None], axis=(1, 2)) * jnp.int64(1000003)
         + jnp.sum(cnt.astype(jnp.int64) * wc[None], axis=1) * jnp.int64(8191)
         + jnp.sum(miss.astype(jnp.int64) * (wc + 7)[None], axis=1))
    return h + (jnp.asarray(answerer, jnp.int64) + 1) * jnp.int64(_SALT)


def _is_member(idx, shards: tuple):
    """Traced membership of a traced shard index in a static tuple."""
    if not shards:
        return jnp.zeros((), bool)
    return jnp.any(idx == jnp.asarray(shards))


def auto_bucket_cap(batch: int, num_shards: int) -> int:
    """Default per-destination probe bucket capacity: 2x the uniform share
    (skew headroom), floored at 32, never beyond `batch` (a shard never
    receives more than one copy of each probe, so `batch` is exact)."""
    from repro.common import ceil_div
    return min(batch, max(ceil_div(2 * batch, num_shards), 32))


def a2a_leg_bytes(bucket_cap: int, answer_cap: int,
                  num_shards: int) -> tuple[int, int]:
    """Static per-shard a2a payload of ONE dist_probe round, split by
    wire leg: ``(probe_leg, answer_leg)`` bytes. The probe leg ships the
    per-destination (lo, hi) bucket records out; the answer leg returns
    ``answer_cap`` key slots + count + missed per bucket slot. The local
    diagonal block never crosses the network and is excluded. Defined
    here next to ``_dist_probe_a2a`` — the function that IS the wire
    format — so a record-layout change updates its accounting in the
    same file; ``bgp.a2a_step_payload_bytes`` sums the two legs."""
    s = num_shards
    probe = (s - 1) * bucket_cap * (8 + 8)
    answer = (s - 1) * bucket_cap * (answer_cap * 8 + 4 + 4)
    return probe, answer


def _dist_probe_a2a(lo, hi, flt, msk, eq_positions, local_keys,
                    probe_cap: int, axis: str, impl: str, splits,
                    bucket_cap: int, fault=None, with_check: bool = False):
    """Point-to-point routed GET (the paper's region-server RPC).

    Four phases, two all_to_all rounds, zero all_gathers:
      1. route   — (B, S) hit matrix from the stored region boundaries
                   (range_intersects_region: exact, keys unique + globally
                   sorted), bucket each probe record — just (lo, hi), the
                   residual filters STAY on the origin shard — per
                   destination region with `bucket_rows`;
      2. ship    — one all_to_all moves every bucket to its region server;
      3. answer  — local rank-find + range gather on the received records.
                   The in-range mask of a sorted-range gather is a
                   front-aligned PREFIX, so the answer block needs no
                   compaction at all before the return trip;
      4. return  — a second all_to_all routes (raw range entries, counts,
                   missed) back; the sender claims them by its recorded
                   bucket slots, offset-composes counts in shard (= global
                   key) order — gather-formulated (source block + in-block
                   position per OUTPUT slot), because XLA serializes
                   scatters on CPU hosts — and applies the residual
                   filters it kept. A fat row spanning regions still
                   concatenates exactly once, in key order.

    Filtering at the origin instead of the region server: on this
    static-shape substrate the return leg ships probe_cap-padded blocks
    either way, so the paper's server-side predicate push-down saves no
    wire bytes here — but pushing it past the collective means the answer
    phase is a pure prefix gather (no sort/scatter compaction of
    filter-holed masks, formerly the dominant cost of the whole routed
    cascade on a host mesh), and the probe record shrinks to two keys.
    Truncation semantics match the local `probe()` exactly: the first
    probe_cap RANGE entries are considered and the rest are surfaced as
    missed — under generous caps (no truncation anywhere) results stay
    bit-identical to the broadcast path.

    Bucket overflow (more probes routed to one region than `bucket_cap`)
    drops the spilled copies and surfaces them in the returned missed
    counts — size `bucket_cap` at the per-destination load (== B for a
    drop-free guarantee).

    Answer-leg integrity (`with_check=True`, DESIGN.md §7): every
    answering shard ships a salted positional checksum per outgoing
    answer block on the return leg; the origin recomputes it over what
    arrived and ZEROES any mismatched block before its keys can enter a
    result — corrupted or dropped answers can make rows go missing
    (surfaced via the extra `bad` output, which the serving engine
    retries on) but never produce a wrong row. `fault` is the chaos
    hook: a static ``(drop_shards, corrupt_shards)`` pair naming
    answering shards whose outgoing legs are zeroed (checksum included:
    lost packets) or value-perturbed AFTER checksumming (wire
    corruption). With checking on, both are detected and quarantined;
    faults without checking are the (test-only) way to demonstrate what
    silent corruption would do. Returns a 4th element ``bad`` — the
    count of quarantined blocks on this origin shard — iff `with_check`.
    """
    S = _axis_size(axis)
    B = lo.shape[0]
    sp = jnp.asarray(splits)
    send = range_intersects_region(lo[:, None], hi[:, None],
                                   sp[None, :-1], sp[None, 1:])
    send = send & (hi > lo)[:, None]
    (slo, shi), slot, drop_cnt = bucket_rows(send, bucket_cap, [lo, hi])
    # --- ship probe records point-to-point (keys-only traffic, O(B)) ---
    rlo = _a2a(slo, axis).reshape(S * bucket_cap)
    rhi = _a2a(shi, axis).reshape(S * bucket_cap)
    # --- answer locally (each record was routed here on purpose) ---
    k, valid, missed = gather_range(local_keys, rlo, rhi, probe_cap, impl)
    cnt = jnp.sum(valid, axis=-1).astype(jnp.int32)     # prefix length
    ans = jnp.where(valid, k + 1, 0)                    # front-aligned; 0 == empty
    ans_b = ans.reshape(S, bucket_cap, probe_cap)
    cnt_b = cnt.reshape(S, bucket_cap)
    miss_b = missed.reshape(S, bucket_cap)
    drop_sh, corrupt_sh = fault if fault is not None else ((), ())
    if with_check or drop_sh or corrupt_sh:
        me = jax.lax.axis_index(axis)
        chk = _leg_checksum(ans_b, cnt_b, miss_b, me)   # (S,) per dest block
        if corrupt_sh:        # wire corruption: perturb AFTER checksumming
            bad_src = _is_member(me, corrupt_sh)
            ans_b = jnp.where(bad_src, ans_b + (ans_b > 0), ans_b)
        if drop_sh:           # lost packets: data AND checksum zeroed
            lost = _is_member(me, drop_sh)
            ans_b = jnp.where(lost, 0, ans_b)
            cnt_b = jnp.where(lost, 0, cnt_b)
            miss_b = jnp.where(lost, 0, miss_b)
            chk = jnp.where(lost, 0, chk)
    # --- route raw range entries home (matches-only traffic) ---
    ANS = _a2a(ans_b, axis)
    CNT = _a2a(cnt_b, axis)
    MISS = _a2a(miss_b, axis)
    bad = jnp.zeros((), jnp.int32)
    if with_check:
        # the return a2a puts answerer s's block at position s: recompute
        # each block's checksum with THAT shard's salt and quarantine
        # (zero) mismatches before any key can reach a result row
        CHK = _a2a(chk, axis)                           # (S,) chk_s[me]
        got = _leg_checksum(ANS, CNT, MISS,
                            jnp.arange(S, dtype=jnp.int64))
        blk_ok = got == CHK                             # (S,)
        bad = jnp.sum(~blk_ok).astype(jnp.int32)
        ANS = jnp.where(blk_ok[:, None, None], ANS, 0)
        CNT = jnp.where(blk_ok[:, None], CNT, 0)
        MISS = jnp.where(blk_ok[:, None], MISS, 0)
    # claim this shard's answers by bucket slot (block s answered shard s)
    dest = jnp.arange(S)[None, :]
    claim_ok = slot < bucket_cap                        # dropped copies -> 0
    sl = jnp.minimum(slot, bucket_cap - 1)
    cnt_bs = jnp.where(claim_ok, CNT[dest, sl], 0)      # (B, S)
    miss_bs = jnp.where(claim_ok, MISS[dest, sl], 0)
    # --- offset-compose counts in shard (= global key) order ---
    # gather-formulated, and DIRECT: resolve each OUTPUT slot p to its
    # (source block, in-block position) from the counts alone, then gather
    # the B x probe_cap selected entries straight out of the a2a answer
    # buffer — never materializing the (B, S, probe_cap) claimed view (XLA
    # serializes the scatter alternative on CPU hosts, and the full view
    # is S x more memory traffic than the result).
    cum = jnp.cumsum(cnt_bs, axis=1)                    # (B, S)
    off = cum - cnt_bs
    total = cum[:, -1]
    p = jnp.arange(probe_cap)[None, :]                  # output slots (1, P)
    src = jnp.sum((cum[:, :, None] <= p[:, None, :]).astype(jnp.int32),
                  axis=1)                               # (B, P) source block
    src = jnp.minimum(src, S - 1)
    j = p - jnp.take_along_axis(off, src, axis=1)       # in-block position
    slot_sel = jnp.take_along_axis(sl, src, axis=1)     # (B, P) bucket slot
    mine = ANS.reshape(S * bucket_cap * probe_cap)[
        (src * bucket_cap + slot_sel) * probe_cap + j]
    mine = jnp.where(p < total[:, None], mine, 0)
    mv = mine > 0
    mk = jnp.where(mv, mine - 1, 0)
    # --- residual predicate filtering, applied by the origin shard ---
    mv = apply_residual(mk, mv, flt, msk, eq_positions)
    my_missed = (jnp.sum(miss_bs, axis=1) + jnp.maximum(total - probe_cap, 0)
                 + drop_cnt)
    if with_check:
        return mk, mv, my_missed.astype(jnp.int32), bad
    return mk, mv, my_missed.astype(jnp.int32)


def dist_probe(lo, hi, flt, msk, eq_positions, local_keys, probe_cap: int,
               axis: str, impl: str = "jnp", region=None,
               routing: str = "broadcast", splits=None, bucket_cap: int = 0,
               fault=None, with_check: bool = False):
    """Distributed GET: ship probe keys, answer locally, scatter matches
    back to origin shards. lo/hi: (B,) local probes. Returns (k (B, cap),
    valid (B, cap), missed (B,)) on the origin shard.

    routing="a2a" (requires `splits`, the full (S+1,) region boundaries)
    dispatches each probe only to the shards its range intersects via
    _dist_probe_a2a — the point-to-point production path. The broadcast
    body below is the validated reference; both return identical results.

    With `region` = this shard's (excl_lo, incl_hi] key bounds (the stored
    HBase-style region boundaries), probes whose [lo, hi) range cannot
    intersect the local slice are masked to empty BEFORE the rank-find /
    residual / compaction work — the region-server routing HBase gives the
    paper for free. Exact, not heuristic: keys are unique and globally
    sorted across shards, so a range misses the region iff lo > incl_hi or
    hi <= excl_lo + 1; masking such probes cannot change any result."""
    if routing == "a2a":
        if splits is None:
            raise ValueError("routing='a2a' needs the stored region splits")
        S = _axis_size(axis)
        cap = bucket_cap if bucket_cap > 0 else auto_bucket_cap(lo.shape[0], S)
        return _dist_probe_a2a(lo, hi, flt, msk, eq_positions, local_keys,
                               probe_cap, axis, impl, splits, cap,
                               fault=fault, with_check=with_check)
    if routing != "broadcast":
        raise ValueError(f"unknown routing {routing!r}")
    if fault is not None or with_check:
        raise ValueError("fault injection / answer-leg checksums hook the "
                         "a2a answer leg — routing='broadcast' has none")
    S = _axis_size(axis)
    B = lo.shape[0]
    me = jax.lax.axis_index(axis)
    # --- ship probe keys (keys-only traffic) ---
    LO = jax.lax.all_gather(lo, axis).reshape(S * B)
    HI = jax.lax.all_gather(hi, axis).reshape(S * B)
    FLT = jax.lax.all_gather(flt, axis).reshape(S * B, 3)
    if region is not None:   # split-aware routing: answer only what we own
        hit = range_intersects_region(LO, HI, *region)
        LO = jnp.where(hit, LO, 0)
        HI = jnp.where(hit, HI, 0)
    # --- local index lookups (each shard answers its key range) ---
    k, valid, missed = gather_range(local_keys, LO, HI, probe_cap, impl)
    valid = apply_residual(k, valid, FLT, msk, eq_positions)
    cnt = jnp.sum(valid, axis=-1).astype(jnp.int32)              # (S*B,)
    # --- compose per-shard offsets so concatenation is exact ---
    CNT = jax.lax.all_gather(cnt, axis)                          # (S, S*B)
    offset = jnp.where(jnp.arange(S)[:, None] < me, CNT, 0).sum(0)
    total = CNT.sum(0)                                           # (S*B,)
    pos = jnp.cumsum(valid, axis=-1) - 1 + offset[:, None]
    keep = valid & (pos < probe_cap)
    slot = jnp.where(keep, pos, probe_cap)
    buf = jnp.zeros((S * B, probe_cap + 1), jnp.int64)
    buf = buf.at[jnp.arange(S * B)[:, None], slot].set(
        jnp.where(keep, k + 1, 0))                               # +1: 0 == empty
    buf = buf[:, :probe_cap].reshape(S, B, probe_cap)
    # --- ship matches back (matches-only traffic) ---
    mine = jax.lax.psum_scatter(buf, axis, scatter_dimension=0, tiled=True)
    mine = mine.reshape(B, probe_cap)
    mv = mine > 0
    mk = jnp.where(mv, mine - 1, 0)
    MISS = jax.lax.psum(missed, axis) + jnp.maximum(total - probe_cap, 0)
    my_missed = jax.lax.dynamic_slice_in_dim(MISS, me * B, B)
    return mk, mv, my_missed.astype(jnp.int32)


def dist_mapsin_step(bnd: Bindings, pattern, local_keys, probe_cap: int,
                     out_cap: int, axis: str, impl: str = "jnp",
                     shard_splits=None, routing: str = "broadcast",
                     bucket_cap: int = 0) -> Bindings:
    """Algorithm 1, distributed: Omega stays in place; only keys + matches move."""
    from repro.core.mapsin import merge_bindings
    plan = make_plan(pattern, bnd.vars)
    lo, hi = probe_ranges(plan, bnd.table)
    lo = jnp.where(bnd.valid, lo, 0)
    hi = jnp.where(bnd.valid, hi, 0)
    flt, msk = residual_values(plan, bnd.table)
    k, valid, missed = dist_probe(lo, hi, flt, msk, plan.eq_positions,
                                  local_keys, probe_cap, axis, impl,
                                  region=_my_region(shard_splits, axis),
                                  routing=routing, splits=shard_splits,
                                  bucket_cap=bucket_cap)
    return merge_bindings(bnd, plan, k, valid, missed, out_cap)


def _multiway_local_merge(bnd: Bindings, plans, k, in_row, missed,
                          row_cap: int, out_cap: int) -> Bindings:
    """Local tail of the multiway star join: per-pattern filtering of the
    fetched row + iterative merge (Algorithm 3 lines after the GET). Shared
    by the per-query distributed step and — vmapped over a leading query
    axis — the batched serving path."""
    out = bnd
    cur_origin = jnp.arange(bnd.capacity, dtype=jnp.int32)
    for plan in plans:
        flt, msk = residual_values(plan, bnd.table)
        extra_vals = jnp.zeros((bnd.capacity, 3), jnp.int64)
        extra_msk = [False, False, False]
        from repro.core.plan import _resolve
        for pos, sc in enumerate(plan.prefix[1:], start=1):
            extra_vals = extra_vals.at[:, pos].set(_resolve(sc, bnd.table))
            extra_msk[pos] = True
        match = apply_residual(k, in_row, flt, msk, plan.eq_positions)
        match = apply_residual(k, match, extra_vals, tuple(extra_msk))
        km = k[cur_origin]
        mm = match[cur_origin] & out.valid[:, None]
        t = unpack3(km)
        old = jnp.broadcast_to(out.table[:, None, :],
                               (out.capacity, row_cap, len(out.vars)))
        new_cols = [t[pos][..., None] for _, pos in plan.out_vars]
        rows = jnp.concatenate([old] + new_cols, -1) if new_cols else old
        ori = jnp.broadcast_to(cur_origin[:, None], (out.capacity, row_cap))
        rows = jnp.concatenate([rows, ori[..., None]], -1)
        table, vmask, dropped = compact(
            rows.reshape(out.capacity * row_cap, -1).astype(jnp.int32),
            mm.reshape(-1), out_cap)
        cur_origin = table[:, -1]
        out = Bindings(out.vars + plan.out_var_names, table[:, :-1], vmask,
                       out.overflow + dropped)
    overflow = out.overflow + jnp.sum(
        jnp.where(bnd.valid, missed, 0)).astype(jnp.int32)
    return Bindings(out.vars, out.table, out.valid, overflow)


def dist_multiway_step(bnd: Bindings, patterns: Sequence, local_keys,
                       row_cap: int, out_cap: int, axis: str,
                       impl: str = "jnp", shard_splits=None,
                       routing: str = "broadcast",
                       bucket_cap: int = 0) -> Bindings:
    """Algorithm 3, distributed: ONE row-GET round answers all star patterns
    (saves n-1 collective rounds — the paper's n-1 GETs per mapping)."""
    plans = [make_plan(p, bnd.vars) for p in patterns]
    p0 = plans[0]
    lo, hi = row_range(p0, bnd.table)
    lo = jnp.where(bnd.valid, lo, 0)
    hi = jnp.where(bnd.valid, hi, 0)
    no_flt = jnp.zeros((bnd.capacity, 3), jnp.int64)
    k, in_row, missed = dist_probe(lo, hi, no_flt, (False,) * 3, (),
                                   local_keys, row_cap, axis, impl,
                                   region=_my_region(shard_splits, axis),
                                   routing=routing, splits=shard_splits,
                                   bucket_cap=bucket_cap)
    return _multiway_local_merge(bnd, plans, k, in_row, missed, row_cap,
                                 out_cap)


# ---------------------------------------------------------------------------
# Batched distributed steps (leading query axis — the sharded serving path)
# ---------------------------------------------------------------------------
#
# A serving batch is Q independent queries of one template. Probing each
# query through its own dist_probe would pay Q collective rounds per
# cascade step; instead the (Q, cap) probe set is FLATTENED to one
# (Q*cap,) record vector, routed through a single dist_probe (one
# all_to_all pair on the a2a path — the whole batch shares the
# collective), and the strictly-local merge is vmapped back over the
# query axis. Bit-identical to running dist_probe per query: routing,
# answering, and offset composition are per-record and order-preserving,
# so flattening only concatenates independent probe sets.


def dist_probe_batched(lo, hi, flt, msk, eq_positions, local_keys,
                       probe_cap: int, axis: str, impl: str = "jnp",
                       region=None, routing: str = "broadcast", splits=None,
                       bucket_cap: int = 0, fault=None,
                       with_check: bool = False):
    """dist_probe over a leading query axis: lo/hi (Q, B), flt (Q, B, 3).
    ONE collective round serves all Q queries; with routing="a2a" the
    per-destination `bucket_cap` is sized for the whole flattened batch
    (the serving engine amortizes the per-query tuned cap: batch x tuned).
    Returns (k (Q, B, cap), valid (Q, B, cap), missed (Q, B)); with
    ``with_check`` a scalar `bad` (quarantined answer-block count, summed
    over the shared collective round) is appended."""
    q, b = lo.shape
    out = dist_probe(
        lo.reshape(q * b), hi.reshape(q * b), flt.reshape(q * b, 3), msk,
        eq_positions, local_keys, probe_cap, axis, impl, region=region,
        routing=routing, splits=splits, bucket_cap=bucket_cap,
        fault=fault, with_check=with_check)
    k, valid, missed = out[:3]
    shaped = (k.reshape(q, b, probe_cap), valid.reshape(q, b, probe_cap),
              missed.reshape(q, b))
    return shaped + (out[3],) if with_check else shaped


def batched_dist_mapsin_step(bnd: Bindings, pattern, local_keys,
                             probe_cap: int, out_cap: int, axis: str,
                             impl: str = "jnp", shard_splits=None,
                             routing: str = "broadcast",
                             bucket_cap: int = 0, fault=None,
                             with_check: bool = False) -> Bindings:
    """dist_mapsin_step over batched Bindings (table (Q, cap, nv), valid
    (Q, cap), overflow (Q,)): one shared collective round, vmapped local
    merge. With ``with_check`` returns ``(Bindings, bad)`` — `bad` is the
    scalar quarantined-answer-block count for this step's collective."""
    from repro.core.mapsin import merge_bindings
    q, cap, nv = bnd.table.shape
    plan = make_plan(pattern, bnd.vars)
    flat = bnd.table.reshape(q * cap, nv)
    lo, hi = probe_ranges(plan, flat)
    v = bnd.valid.reshape(q * cap)
    lo = jnp.where(v, lo, 0)
    hi = jnp.where(v, hi, 0)
    flt, msk = residual_values(plan, flat)
    out = dist_probe_batched(
        lo.reshape(q, cap), hi.reshape(q, cap), flt.reshape(q, cap, 3), msk,
        plan.eq_positions, local_keys, probe_cap, axis, impl,
        region=_my_region(shard_splits, axis), routing=routing,
        splits=shard_splits, bucket_cap=bucket_cap,
        fault=fault, with_check=with_check)
    k, valid, missed = out[:3]
    merge = lambda b, kk, vv, mm: merge_bindings(b, plan, kk, vv, mm, out_cap)
    merged = jax.vmap(merge)(bnd, k, valid, missed)
    return (merged, out[3]) if with_check else merged


def batched_dist_multiway_step(bnd: Bindings, patterns: Sequence, local_keys,
                               row_cap: int, out_cap: int, axis: str,
                               impl: str = "jnp", shard_splits=None,
                               routing: str = "broadcast",
                               bucket_cap: int = 0, fault=None,
                               with_check: bool = False) -> Bindings:
    """dist_multiway_step over batched Bindings: the single row-GET round
    is shared by the whole batch, the per-pattern merge tail is vmapped.
    With ``with_check`` returns ``(Bindings, bad)``."""
    q, cap, nv = bnd.table.shape
    plans = [make_plan(p, bnd.vars) for p in patterns]
    p0 = plans[0]
    flat = bnd.table.reshape(q * cap, nv)
    lo, hi = row_range(p0, flat)
    v = bnd.valid.reshape(q * cap)
    lo = jnp.where(v, lo, 0).reshape(q, cap)
    hi = jnp.where(v, hi, 0).reshape(q, cap)
    no_flt = jnp.zeros((q, cap, 3), jnp.int64)
    out = dist_probe_batched(
        lo, hi, no_flt, (False,) * 3, (), local_keys, row_cap, axis, impl,
        region=_my_region(shard_splits, axis), routing=routing,
        splits=shard_splits, bucket_cap=bucket_cap,
        fault=fault, with_check=with_check)
    k, in_row, missed = out[:3]
    merge = lambda b, kk, rr, mm: _multiway_local_merge(
        b, plans, kk, rr, mm, row_cap, out_cap)
    merged = jax.vmap(merge)(bnd, k, in_row, missed)
    return (merged, out[3]) if with_check else merged


# ---------------------------------------------------------------------------
# Repartitioning (the reduce-side shuffle primitive)
# ---------------------------------------------------------------------------


def repartition(table: jnp.ndarray, valid: jnp.ndarray, key: jnp.ndarray,
                bucket_cap: int, axis: str):
    """Hash-partition rows by key across shards (the shuffle phase).

    Returns (table (S*cap, nv), valid, dropped) — rows received by this shard.
    """
    S = _axis_size(axis)
    n, nv = table.shape
    send = valid[:, None] & (key[:, None] % S == jnp.arange(S)[None, :])
    (buf, vbuf), _, drop_cnt = bucket_rows(send, bucket_cap, [table, valid])
    # the shuffle: BOTH relations cross the network in full
    recv = _a2a(buf, axis)
    vrecv = _a2a(vbuf, axis)
    return (recv.reshape(S * bucket_cap, nv), vrecv.reshape(S * bucket_cap),
            jax.lax.psum(jnp.sum(drop_cnt), axis))
