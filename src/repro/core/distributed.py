"""Distributed MAPSIN execution: shard_map + explicit collectives.

Traffic model (the faithful translation of the paper's network argument):
  MAPSIN step   — all_gather(probe keys)  +  psum_scatter(matches)
                  == ship ONLY probe keys and ONLY matching tuples.
  reduce-side   — all_to_all(BOTH full relations)  (see reduce_side.py)

The store is range-sharded; a probe whose key range spans several shards
(fat rows, the `rdf:type` problem) is answered by every intersecting shard
and the per-shard match counts are offset-composed, so results concatenate
exactly once — the compound-rowkey fix without compound keys.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.mapsin import Bindings, apply_residual, compact, gather_range
from repro.core.plan import make_plan, probe_ranges, residual_values, row_range
from repro.core.rdf import unpack3
from repro.core.triple_store import range_intersects_region


def _axis_size(axis: str) -> int:
    return jax.lax.psum(1, axis)


def _my_region(shard_splits, axis: str):
    """This shard's (last-key-of-previous-shard, last-own-key] bounds from
    the stored region boundaries (triple_store splits arrays)."""
    if shard_splits is None:
        return None
    sp = jnp.asarray(shard_splits)
    me = jax.lax.axis_index(axis)
    return jnp.take(sp, me), jnp.take(sp, me + 1)


def dist_probe(lo, hi, flt, msk, eq_positions, local_keys, probe_cap: int,
               axis: str, impl: str = "jnp", region=None):
    """Distributed GET: ship probe keys, answer locally, scatter matches
    back to origin shards. lo/hi: (B,) local probes. Returns (k (B, cap),
    valid (B, cap), missed (B,)) on the origin shard.

    With `region` = this shard's (excl_lo, incl_hi] key bounds (the stored
    HBase-style region boundaries), probes whose [lo, hi) range cannot
    intersect the local slice are masked to empty BEFORE the rank-find /
    residual / compaction work — the region-server routing HBase gives the
    paper for free. Exact, not heuristic: keys are unique and globally
    sorted across shards, so a range misses the region iff lo > incl_hi or
    hi <= excl_lo + 1; masking such probes cannot change any result."""
    S = _axis_size(axis)
    B = lo.shape[0]
    me = jax.lax.axis_index(axis)
    # --- ship probe keys (keys-only traffic) ---
    LO = jax.lax.all_gather(lo, axis).reshape(S * B)
    HI = jax.lax.all_gather(hi, axis).reshape(S * B)
    FLT = jax.lax.all_gather(flt, axis).reshape(S * B, 3)
    if region is not None:   # split-aware routing: answer only what we own
        hit = range_intersects_region(LO, HI, *region)
        LO = jnp.where(hit, LO, 0)
        HI = jnp.where(hit, HI, 0)
    # --- local index lookups (each shard answers its key range) ---
    k, valid, missed = gather_range(local_keys, LO, HI, probe_cap, impl)
    valid = apply_residual(k, valid, FLT, msk, eq_positions)
    cnt = jnp.sum(valid, axis=-1).astype(jnp.int32)              # (S*B,)
    # --- compose per-shard offsets so concatenation is exact ---
    CNT = jax.lax.all_gather(cnt, axis)                          # (S, S*B)
    offset = jnp.where(jnp.arange(S)[:, None] < me, CNT, 0).sum(0)
    total = CNT.sum(0)                                           # (S*B,)
    pos = jnp.cumsum(valid, axis=-1) - 1 + offset[:, None]
    keep = valid & (pos < probe_cap)
    slot = jnp.where(keep, pos, probe_cap)
    buf = jnp.zeros((S * B, probe_cap + 1), jnp.int64)
    buf = buf.at[jnp.arange(S * B)[:, None], slot].set(
        jnp.where(keep, k + 1, 0))                               # +1: 0 == empty
    buf = buf[:, :probe_cap].reshape(S, B, probe_cap)
    # --- ship matches back (matches-only traffic) ---
    mine = jax.lax.psum_scatter(buf, axis, scatter_dimension=0, tiled=True)
    mine = mine.reshape(B, probe_cap)
    mv = mine > 0
    mk = jnp.where(mv, mine - 1, 0)
    MISS = jax.lax.psum(missed, axis) + jnp.maximum(total - probe_cap, 0)
    my_missed = jax.lax.dynamic_slice_in_dim(MISS, me * B, B)
    return mk, mv, my_missed.astype(jnp.int32)


def dist_mapsin_step(bnd: Bindings, pattern, local_keys, probe_cap: int,
                     out_cap: int, axis: str, impl: str = "jnp",
                     shard_splits=None) -> Bindings:
    """Algorithm 1, distributed: Omega stays in place; only keys + matches move."""
    from repro.core.mapsin import merge_bindings
    plan = make_plan(pattern, bnd.vars)
    lo, hi = probe_ranges(plan, bnd.table)
    lo = jnp.where(bnd.valid, lo, 0)
    hi = jnp.where(bnd.valid, hi, 0)
    flt, msk = residual_values(plan, bnd.table)
    k, valid, missed = dist_probe(lo, hi, flt, msk, plan.eq_positions,
                                  local_keys, probe_cap, axis, impl,
                                  region=_my_region(shard_splits, axis))
    return merge_bindings(bnd, plan, k, valid, missed, out_cap)


def dist_multiway_step(bnd: Bindings, patterns: Sequence, local_keys,
                       row_cap: int, out_cap: int, axis: str,
                       impl: str = "jnp", shard_splits=None) -> Bindings:
    """Algorithm 3, distributed: ONE row-GET round answers all star patterns
    (saves n-1 collective rounds — the paper's n-1 GETs per mapping)."""
    plans = [make_plan(p, bnd.vars) for p in patterns]
    p0 = plans[0]
    lo, hi = row_range(p0, bnd.table)
    lo = jnp.where(bnd.valid, lo, 0)
    hi = jnp.where(bnd.valid, hi, 0)
    no_flt = jnp.zeros((bnd.capacity, 3), jnp.int64)
    k, in_row, missed = dist_probe(lo, hi, no_flt, (False,) * 3, (),
                                   local_keys, row_cap, axis, impl,
                                   region=_my_region(shard_splits, axis))
    # local per-pattern filtering + iterative merge — reuse the local kernel
    from repro.core import mapsin as local
    out = bnd
    cur_origin = jnp.arange(bnd.capacity, dtype=jnp.int32)
    for plan in plans:
        flt, msk = residual_values(plan, bnd.table)
        extra_vals = jnp.zeros((bnd.capacity, 3), jnp.int64)
        extra_msk = [False, False, False]
        from repro.core.plan import _resolve
        for pos, sc in enumerate(plan.prefix[1:], start=1):
            extra_vals = extra_vals.at[:, pos].set(_resolve(sc, bnd.table))
            extra_msk[pos] = True
        match = apply_residual(k, in_row, flt, msk, plan.eq_positions)
        match = apply_residual(k, match, extra_vals, tuple(extra_msk))
        km = k[cur_origin]
        mm = match[cur_origin] & out.valid[:, None]
        t = unpack3(km)
        old = jnp.broadcast_to(out.table[:, None, :],
                               (out.capacity, row_cap, len(out.vars)))
        new_cols = [t[pos][..., None] for _, pos in plan.out_vars]
        rows = jnp.concatenate([old] + new_cols, -1) if new_cols else old
        ori = jnp.broadcast_to(cur_origin[:, None], (out.capacity, row_cap))
        rows = jnp.concatenate([rows, ori[..., None]], -1)
        table, vmask, dropped = compact(
            rows.reshape(out.capacity * row_cap, -1).astype(jnp.int32),
            mm.reshape(-1), out_cap)
        cur_origin = table[:, -1]
        out = Bindings(out.vars + plan.out_var_names, table[:, :-1], vmask,
                       out.overflow + dropped)
    overflow = out.overflow + jnp.sum(
        jnp.where(bnd.valid, missed, 0)).astype(jnp.int32)
    return Bindings(out.vars, out.table, out.valid, overflow)


# ---------------------------------------------------------------------------
# Repartitioning (the reduce-side shuffle primitive)
# ---------------------------------------------------------------------------


def repartition(table: jnp.ndarray, valid: jnp.ndarray, key: jnp.ndarray,
                bucket_cap: int, axis: str):
    """Hash-partition rows by key across shards (the shuffle phase).

    Returns (table (S*cap, nv), valid, dropped) — rows received by this shard.
    """
    S = _axis_size(axis)
    n, nv = table.shape
    dest = jnp.where(valid, key % S, S)                   # invalid -> sentinel
    order = jnp.argsort(dest)
    rows, dsort, vsort = table[order], dest[order], valid[order]
    start = jnp.searchsorted(dsort, jnp.arange(S))
    slot = jnp.arange(n) - start[jnp.minimum(dsort, S - 1)]
    keep = vsort & (slot < bucket_cap) & (dsort < S)
    slot = jnp.where(keep, slot, bucket_cap)
    buf = jnp.zeros((S, bucket_cap + 1, nv), table.dtype)
    buf = buf.at[jnp.minimum(dsort, S - 1), slot].set(
        jnp.where(keep[:, None], rows, 0))
    vbuf = jnp.zeros((S, bucket_cap + 1), bool)
    vbuf = vbuf.at[jnp.minimum(dsort, S - 1), slot].set(keep)
    buf, vbuf = buf[:, :bucket_cap], vbuf[:, :bucket_cap]
    dropped = jnp.sum(vsort & (dsort < S) & ~keep).astype(jnp.int32)
    # the shuffle: BOTH relations cross the network in full
    recv = jax.lax.all_to_all(buf, axis, split_axis=0, concat_axis=0, tiled=True)
    vrecv = jax.lax.all_to_all(vbuf, axis, split_axis=0, concat_axis=0, tiled=True)
    return (recv.reshape(S * bucket_cap, nv), vrecv.reshape(S * bucket_cap),
            jax.lax.psum(dropped, axis))
