"""MAPSIN join engine — the paper's core contribution (DESIGN.md §1-2, §6)."""
from repro.core.bgp import (  # noqa: F401
    ExecConfig, execute_local, execute_sharded, plan_steps, query_traffic,
    rows_set,
)
from repro.core.mapsin import Bindings, mapsin_step, multiway_step, scan_pattern  # noqa: F401
from repro.core.oracle import execute_oracle  # noqa: F401
from repro.core.planner import (  # noqa: F401
    Caps, LogicalPlan, PhysicalPlan, PlanStep, compile_plan, explain,
    quantize_cap,
)
from repro.core.rdf import Dictionary, Pattern, pack3, unpack3  # noqa: F401
from repro.core.triple_store import TripleStore, build_store  # noqa: F401
