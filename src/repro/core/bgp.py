"""BGP executors over the planner's ``PhysicalPlan`` IR (DESIGN.md §1/§6).

Planning lives in ``core/planner.py``: ``compile_plan`` turns a pattern
list into a ``PhysicalPlan`` whose steps each carry their own operator
(``scan | mapsin | multiway | reduce_side``) and static capacities
(``Caps``). Every executor here CONSUMES a plan; passing a raw
``Sequence[Pattern]`` still works — the entry points are thin
plan-then-execute wrappers. ``ExecConfig`` is runtime-only: kernel
``impl``, collective ``routing``, and the ``reorder`` escape hatch.

Execution model (the fused probe engine, this module's layer of it):
the whole cascade — the first-pattern scan plus every step — is compiled
as ONE jitted function per (plan, cfg) and cached, so ``execute_local``
pays a single dispatch per query instead of ~6 eager ops per step, and
the initial Bindings buffers are donated to the computation (active on
accelerator backends). Host syncs (``int(count())`` per step) happen
only on the opt-in ``stats=`` instrumentation path, which records the
ACTUAL row counts, the per-step overflow counters (probe/out-cap drops
— surfaced, never silent), and the measured probe->region fan-out that
feeds ``query_traffic_actual``'s routed model and the planner's a2a
capacity embedding.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import distributed as dist
from repro.core import mapsin as ms
from repro.core import reduce_side as rs
from repro.core.plan import make_plan
from repro.core.planner import (  # noqa: F401  (re-exported API surface)
    ALL_OPERATORS, Caps, LogicalPlan, PhysicalPlan, PlanStep, _host_keys,
    compile_plan, explain, order_patterns, pattern_cardinality, quantize_cap)
from repro.core.rdf import Pattern
from repro.core.triple_store import TripleStore


@dataclasses.dataclass(frozen=True)
class ExecConfig:
    """Runtime-only knobs. Capacities and planning options moved to the
    planner (``Caps`` / ``compile_plan`` arguments) — a capacity is a
    compile-time shape constant carried by the plan, not a runtime flag."""
    impl: str = "jnp"            # jnp | pallas | pallas_interpret
    routing: str = "broadcast"   # dist_probe collective: broadcast | a2a
                                 # (a2a = point-to-point region routing)
    reorder: bool = True         # False = execute patterns as given


@dataclasses.dataclass(frozen=True)
class Step:
    """DEPRECATED legacy step (kind: scan | join | multiway). New code
    consumes ``planner.PlanStep`` (which adds per-step caps + estimates);
    this shape survives only for ``plan_steps`` callers."""
    kind: str
    patterns: tuple[Pattern, ...]


def plan_steps(patterns: Sequence[Pattern], caps: Caps | None = None,
               store: TripleStore | None = None, multiway: bool = True,
               reorder: bool = True) -> list[Step]:
    """DEPRECATED: heuristic-ordered legacy steps. Use ``compile_plan``
    (cost-based, per-step operators + caps) and read ``plan.steps``."""
    from repro.core.planner import ENGINE_OPERATORS
    plan = compile_plan(store, patterns, caps or Caps(),
                        ordering="heuristic", multiway=multiway,
                        reorder=reorder, operators=ENGINE_OPERATORS)
    kind_of = {"mapsin": "join", "reduce_side": "join"}
    return [Step(kind_of.get(st.kind, st.kind), st.patterns)
            for st in plan.steps]


def as_plan(store: TripleStore | None, query, mode: str = "mapsin",
            cfg: ExecConfig = ExecConfig(), caps: Caps = Caps(),
            num_shards: int = 0, route_shards: int = 10) -> PhysicalPlan:
    """Resolve a query argument (PhysicalPlan | LogicalPlan | patterns)
    into a PhysicalPlan — the plan-then-execute shim behind every legacy
    entry point."""
    if isinstance(query, PhysicalPlan):
        return query
    return compile_plan(store, query, caps, mode=mode,
                        reorder=cfg.reorder, routing=cfg.routing,
                        num_shards=num_shards, route_shards=route_shards)


# ---------------------------------------------------------------------------
# Traffic accounting (bytes shipped by the collectives; static formulas)
# ---------------------------------------------------------------------------


def step_traffic_bytes(step: PlanStep, mode: str, num_shards: int,
                       n_vars_before: int) -> int:
    """Global bytes crossing the interconnect for one step (padding
    included), from the step's OWN caps.

    Modes:
      mapsin         — the implemented broadcast-GET: probe keys are
                       all-gathered (correct for arbitrarily fat rows), match
                       counts all-gathered, matches psum_scattered home.
                       Pays O(S) on the key/count legs — fine for pods,
                       quantified so §Perf can show the routed win.
      mapsin_routed  — the production point-to-point GET (DESIGN.md §2):
                       each probe travels to its owner shard once (a2a) and
                       its matches travel back once. O(B) — the paper's RPC.
                       The record is two keys + origin bookkeeping; the
                       residual filters never cross the network (applied by
                       the origin shard after the round trip — see
                       _dist_probe_a2a).
      reduce         — shuffle BOTH relations (repartition join).
    """
    s, b = num_shards, step.caps.out_cap
    if s == 1 or step.kind == "scan":
        return 0
    cap = (step.caps.row_cap if step.kind == "multiway"
           else step.caps.probe_cap)
    if step.kind == "reduce_side":
        mode = "reduce"     # a hybrid plan's reduce step shuffles whatever
                            # the comparison mode prices the OTHER steps at
    if mode == "mapsin":
        keys = s * b * (8 + 8 + 24) * (s - 1)          # all_gather lo/hi/filters
        counts = s * (s * b) * 4 * (s - 1)             # all_gather counts
        matches = s * (s * b) * cap * 8                # psum_scatter ring pass
        return keys + counts + matches
    if mode == "mapsin_routed":
        keys = s * b * (8 + 8 + 4)                     # a2a probe records
        matches = s * b * cap * 8                      # a2a matches home
        return keys + matches
    # reduce-side: shuffle Omega and the scanned relation in full
    nv_left = n_vars_before
    per_rel = s * s * step.caps.bucket_cap * 4         # rows x int32 cols
    rounds = len(step.patterns)
    return rounds * (per_rel * (nv_left + 3) + per_rel)  # + validity bytes


def a2a_step_payload_bytes(bucket_cap: int, answer_cap: int,
                           num_shards: int) -> int:
    """Static per-shard a2a collective payload of ONE dist_probe round
    (DESIGN.md §2 wire format): per non-local destination, the probe
    bucket's (lo, hi) records out plus the answer return leg (answer_cap
    key slots + count + missed per bucket slot). The local diagonal block
    never crosses the network and is excluded. The ONE shared formula —
    the serving engine's traffic accounting and both benches call this,
    so a wire-format change (like PR 4's 44->20 B record) lands once;
    the per-leg split lives next to the wire format itself
    (``distributed.a2a_leg_bytes``) and feeds the probe/answer byte
    counters on dispatch spans and metrics."""
    probe, answer = dist.a2a_leg_bytes(bucket_cap, answer_cap, num_shards)
    return probe + answer


def query_traffic(query, mode: str, caps: Caps = Caps(),
                  num_shards: int = 1,
                  store: TripleStore | None = None) -> int:
    """Total modeled interconnect bytes for a query (paper's network
    metric). `query` may be a compiled PhysicalPlan or a pattern list
    (planned heuristically when no store supplies statistics)."""
    plan = as_plan(store, query, caps=caps)
    total = 0
    seen: set[str] = set()
    for st in plan.steps:
        total += step_traffic_bytes(st, mode, num_shards, len(seen))
        for p in st.patterns:
            seen.update(p.variables)
    return total


# ---------------------------------------------------------------------------
# Executors
# ---------------------------------------------------------------------------


def _cascade_body(plan: PhysicalPlan, cfg: ExecConfig):
    """The whole-cascade computation:
    (keys_spo, keys_ops, scratch) -> (Bindings, per-step overflow).

    One traced function per (plan, cfg): every step fuses into a single
    XLA computation, so repeated execution pays one dispatch and zero
    per-step host syncs. `scratch` is the zeroed initial Bindings,
    donated on backends that support donation. Each step runs the
    operator the PLANNER chose for it, at the caps the plan embeds.
    The second output is the (n_steps,) CUMULATIVE overflow counter
    after each step — a handful of scalars riding the existing dispatch,
    so overflow-escalation (DESIGN.md §7) can localize a truncation to
    its step without the instrumented run's per-step host syncs.
    """
    steps = plan.steps
    first = steps[0].patterns[0]
    first_vars = make_plan(first, ()).out_var_names

    def fn(keys_spo, keys_ops, scratch):
        keys_of = lambda pat, dom: (keys_spo if make_plan(pat, dom).index == 0
                                    else keys_ops)
        bnd = ms.scan_pattern(first, keys_of(first, ()),
                              steps[0].caps.out_cap, cfg.impl,
                              scratch=scratch)
        ovfs = [bnd.overflow]
        for st in steps[1:]:
            c = st.caps
            if st.kind == "multiway":
                keys = keys_of(st.patterns[0], bnd.vars)
                bnd = ms.multiway_step(bnd, st.patterns, keys, c.row_cap,
                                       c.out_cap, cfg.impl)
            elif st.kind == "mapsin":
                keys = keys_of(st.patterns[0], bnd.vars)
                bnd = ms.mapsin_step(bnd, st.patterns[0], keys,
                                     c.probe_cap, c.out_cap, cfg.impl)
            else:                # reduce_side: relation scanned fresh
                for pat in st.patterns:
                    bnd = rs.local_reduce_step(bnd, pat, keys_of(pat, ()),
                                               c.scan_cap, c.probe_cap,
                                               c.out_cap, cfg.impl)
            ovfs.append(bnd.overflow)
        return bnd, jnp.stack(ovfs)

    return fn, first_vars


def _compiled_cascade(store: TripleStore, plan: PhysicalPlan,
                      cfg: ExecConfig):
    key = ("cascade", plan, cfg)
    hit = store.plan_cache.get(key)
    if hit is None:
        fn, first_vars = _cascade_body(plan, cfg)
        donate = (2,) if jax.default_backend() in ("tpu", "gpu") else ()
        hit = (jax.jit(fn, donate_argnums=donate), first_vars)
        store.plan_cache[key] = hit
    return hit


def _check_plan_mode(query, mode: str):
    """A compiled plan carries its own operators, so the `mode` argument
    is only meaningful as a reduce-BASELINE request: asking for 'reduce'
    on a mapsin-compiled plan would silently time the wrong engine. (The
    default 'mapsin' with a plan means 'execute the plan as compiled' —
    hybrid plans legitimately contain reduce_side fallback steps.)"""
    if not isinstance(query, PhysicalPlan):
        return
    if mode == "reduce" and any(st.kind in ("mapsin", "multiway")
                                for st in query.steps):
        raise ValueError("mode='reduce' with a compiled mapsin plan — "
                         "operators are baked into the plan; use "
                         "compile_plan(..., mode='reduce') for the baseline")


def execute_local(store: TripleStore, query, mode: str = "mapsin",
                  cfg: ExecConfig = ExecConfig(), caps: Caps = Caps(),
                  stats: list | None = None,
                  route_shards: int | None = None):
    """Single-shard execution (functional reference; also the oracle's peer).

    `query` is a compiled ``PhysicalPlan`` or a raw pattern sequence
    (compiled cost-based on the spot — cached on the store). The default
    path runs the cached whole-cascade jit — no per-step dispatch, no
    host syncs in the timed region. When `stats` is a list (opt-in
    instrumentation, off the hot path), the cascade runs stepwise and
    appends per-step dicts with ACTUAL row counts, the per-step overflow
    counter, and the measured probe->region fan-out — feeding the
    measured traffic model in query_traffic_actual (the paper's network
    metric) and the planner's a2a capacity embedding. An explicit
    `route_shards` overrides the plan's baked-in measurement size; the
    default (None) keeps the plan's (10 when compiling patterns)."""
    _check_plan_mode(query, mode)
    plan = as_plan(store, query, mode, cfg, caps,
                   route_shards=10 if route_shards is None else route_shards)
    if (route_shards is not None and isinstance(query, PhysicalPlan)
            and plan.route_shards != route_shards):
        plan = dataclasses.replace(plan, route_shards=route_shards)
    if stats is not None:
        return _execute_local_instrumented(store, plan, cfg, stats)
    jitted, first_vars = _compiled_cascade(store, plan, cfg)
    scratch = ms.Bindings.empty(first_vars, plan.steps[0].caps.out_cap)
    bnd, step_ovf = jitted(store.flat_keys(0), store.flat_keys(1), scratch)
    # cheap unconditional per-step counters (cumulative, one scalar per
    # step): overflow-escalation can trigger and localize the truncating
    # step without the instrumented run's host syncs. Attached as a plain
    # attribute — Bindings' pytree structure (table, valid, overflow) is
    # unchanged, so every existing consumer is untouched.
    bnd.step_overflow = step_ovf
    return bnd


def _route_splits(store: TripleStore, index: int, s: int) -> np.ndarray:
    """Region boundaries for a hypothetical `s`-shard layout of the index:
    the stored splits when the store is already sharded that way, otherwise
    exactly what build_store would pick (same _shard_sorted rule)."""
    if s == store.num_shards:
        return np.asarray(store.splits(index))
    ck = ("route_splits", index, s)
    if ck not in store.plan_cache:
        from repro.core.triple_store import _shard_sorted
        keys = _host_keys(store, index)
        keys = keys[keys < np.iinfo(np.int64).max]
        _, splits, _ = _shard_sorted(keys, s)
        store.plan_cache[ck] = splits
    return store.plan_cache[ck]


def _probe_fanout(store: TripleStore, plan, bnd: ms.Bindings, s: int,
                  whole_row: bool = False) -> tuple[int, int, int]:
    """Measured routing fan-out if each probe were routed only to shards
    whose key range it intersects — the paper's region-server GET, vs the
    broadcast's n_in * S. Returns (total deliveries, max per-region load,
    max range-entry count per probe); the per-region max sizes the a2a
    per-destination probe buckets and the per-probe max sizes the answer
    return leg (planner.embed_a2a_caps)."""
    from repro.core.plan import probe_ranges, row_range
    lo, hi = (row_range if whole_row else probe_ranges)(plan, bnd.table)
    lo, hi = np.asarray(lo), np.asarray(hi)
    valid = np.asarray(bnd.valid)
    splits = _route_splits(store, plan.index, s)
    from repro.core.triple_store import range_intersects_region
    hits = range_intersects_region(lo[:, None], hi[:, None],
                                   splits[None, :-1], splits[None, 1:])
    per_region = hits[valid].sum(axis=0)
    keys = _host_keys(store, plan.index)
    lens = (np.searchsorted(keys, hi[valid])
            - np.searchsorted(keys, lo[valid]))
    return (int(per_region.sum()), int(per_region.max(initial=0)),
            int(lens.max(initial=0)))


def _execute_local_instrumented(store: TripleStore, plan: PhysicalPlan,
                                cfg: ExecConfig, stats: list):
    import time as _time
    steps = plan.steps
    keys_of = lambda pat, dom: store.flat_keys(make_plan(pat, dom).index)
    s_route = plan.route_shards
    t0 = _time.perf_counter()
    bnd = ms.scan_pattern(steps[0].patterns[0],
                          keys_of(steps[0].patterns[0], ()),
                          steps[0].caps.out_cap, cfg.impl)
    ovf_prev = int(np.asarray(bnd.overflow))
    ovf_cum = [ovf_prev]
    t1 = _time.perf_counter()
    # per-step wall stamps (t0/t1 on the perf_counter clock, wall_s the
    # delta) ride the stats dicts only on this opt-in path — the jitted
    # hot path keeps zero host syncs; obs.trace.spans_from_stats turns
    # them into per-cascade-step trace spans
    stats.append({"kind": "scan", "n_in": 0, "n_out": int(bnd.count()),
                  "nv": len(bnd.vars), "relation": int(bnd.count()),
                  "n_patterns": 1, "overflow": ovf_prev,
                  "t0": t0, "t1": t1, "wall_s": t1 - t0})
    for st in steps[1:]:
        c = st.caps
        t0 = _time.perf_counter()
        n_in, nv_in = int(bnd.count()), len(bnd.vars)
        deliveries = max_region = probe_len = 0
        if st.kind == "multiway":
            keys = keys_of(st.patterns[0], bnd.vars)
            plan0 = make_plan(st.patterns[0], bnd.vars)
            deliveries, max_region, probe_len = _probe_fanout(
                store, plan0, bnd, s_route, whole_row=True)
            bnd = ms.multiway_step(bnd, st.patterns, keys, c.row_cap,
                                   c.out_cap, cfg.impl)
        elif st.kind == "mapsin":
            keys = keys_of(st.patterns[0], bnd.vars)
            plan0 = make_plan(st.patterns[0], bnd.vars)
            deliveries, max_region, probe_len = _probe_fanout(
                store, plan0, bnd, s_route)
            bnd = ms.mapsin_step(bnd, st.patterns[0], keys, c.probe_cap,
                                 c.out_cap, cfg.impl)
        else:                    # reduce_side re-scans with an empty domain
            for pat in st.patterns:
                keys = keys_of(pat, ())
                bnd = rs.local_reduce_step(bnd, pat, keys, c.scan_cap,
                                           c.probe_cap, c.out_cap, cfg.impl)
        n_out = int(bnd.count())         # host sync: the step's work is done
        t1 = _time.perf_counter()        # before the relation-scan extras
        rel = 0
        for pat in st.patterns:
            r = ms.scan_pattern(pat, keys_of(pat, ()), c.scan_cap, cfg.impl)
            rel += int(r.count())
        ovf_now = int(np.asarray(bnd.overflow))
        stats.append({"kind": st.kind, "n_in": n_in,
                      "n_out": n_out, "nv": nv_in,
                      "relation": rel, "n_patterns": len(st.patterns),
                      "deliveries": deliveries, "route_shards": s_route,
                      "deliveries_max_region": max_region,
                      "probe_len_max": probe_len,
                      "overflow": ovf_now - ovf_prev,
                      "t0": t0, "t1": t1, "wall_s": t1 - t0})
        ovf_prev = ovf_now
        ovf_cum.append(ovf_now)
    bnd.step_overflow = jnp.asarray(ovf_cum, jnp.int32)  # same contract as
    return bnd                                           # the jitted path


def query_traffic_actual(stats: list, mode: str, num_shards: int,
                         n_triples: int = 0) -> dict:
    """Data-movement bytes from ACTUAL row counts (vs the static-capacity
    model in query_traffic). Two components, mirroring the paper's setting:

    network — what crosses the interconnect per join step:
      mapsin_routed — split-aware routing: each input mapping's probe
                      record (20 B: lo/hi keys + origin; the residual
                      filters stay on the origin shard since PR 4) travels
                      once per REGION its key range intersects — the
                      MEASURED fan-out recorded by the instrumented
                      executor ("deliveries"; ~1 for point probes, >1 only
                      for fat rows spanning region boundaries) — and each
                      match comes back once (12 B triple);
      mapsin        — broadcast-GET: 44 B probe records (lo/hi + filters +
                      origin) x (S-1), matches once;
      reduce        — Omega + the (already filtered) relation are shuffled.

    scanned — storage bytes read to produce the step's input:
      reduce        — HDFS has NO index: every pattern forces a full pass
                      over the dataset in the map phase (the dominant cost
                      the paper measures for selective queries);
      mapsin        — index GETs: ~log2(N) binary-search touches per probe
                      plus the matched entries only.
    """
    import math
    s = num_shards
    net = 0
    scanned = 0
    routed = broadcast = 0                 # probe records: routed vs x(S-1)
    logn = max(math.ceil(math.log2(max(n_triples, 2))), 1)
    for st in stats:
        rounds = 1 if st["kind"] == "multiway" else st["n_patterns"]
        if st["kind"] == "scan":
            if mode == "reduce":
                scanned += n_triples * 8          # full pass, no index
            else:
                scanned += st["n_out"] * 8 + logn * 8  # index range scan
            continue
        # a planner-selected reduce_side step shuffles and re-scans its
        # relation whatever the comparison mode — pricing it as an index
        # GET (zero probe records) would under-report hybrid plans
        if st["kind"] == "reduce_side" or mode not in ("mapsin",
                                                       "mapsin_routed"):
            row_l = st["nv"] * 4 + 4
            if s > 1:
                net += st["n_patterns"] * (st["n_in"] * row_l
                                           + st["relation"] * 16)
            scanned += st["n_patterns"] * n_triples * 8
            continue
        rec_routed, rec_bcast, match_b = 20, 44, 12
        deliv = (st["deliveries"] if st.get("route_shards") == s
                 and "deliveries" in st else st["n_in"])
        routed += deliv * rec_routed * rounds
        broadcast += st["n_in"] * rec_bcast * (s - 1) * rounds
        if mode == "mapsin_routed":
            if s > 1:
                net += deliv * rec_routed * rounds + st["n_out"] * match_b
            scanned += st["n_in"] * rounds * logn * 8 + st["n_out"] * 8
        else:  # mode == "mapsin" (broadcast probe records)
            if s > 1:
                net += (st["n_in"] * rec_bcast * (s - 1) * rounds
                        + st["n_out"] * match_b)
            scanned += st["n_in"] * rounds * logn * 8 + st["n_out"] * 8
    return {"network": net, "scanned": scanned, "total": net + scanned,
            "probe_bytes_routed": routed, "probe_bytes_broadcast": broadcast}


def apply_dist_step(bnd: ms.Bindings, st: PlanStep, keys, splits,
                    cfg: ExecConfig, axis: str, batched: bool = False,
                    fault=None, with_check: bool = False) -> ms.Bindings:
    """One distributed MAPSIN cascade step (join or multiway star) at the
    step's OWN caps — the shared dispatch behind execute_sharded's
    per-shard body and the serving engine's batched template cascade
    (`batched=True` expects Bindings with a leading query axis and routes
    the whole batch through ONE collective round per step; see
    core/distributed.py). `fault`/`with_check` hook the a2a answer-leg
    integrity machinery (serve/faults.py): with_check returns
    ``(Bindings, bad)`` and requires the batched a2a path."""
    c = st.caps
    extra = ({"fault": fault, "with_check": with_check}
             if batched and (fault is not None or with_check) else {})
    if st.kind == "multiway":
        fn = (dist.batched_dist_multiway_step if batched
              else dist.dist_multiway_step)
        return fn(bnd, st.patterns, keys, c.row_cap, c.out_cap, axis,
                  cfg.impl, shard_splits=splits, routing=cfg.routing,
                  bucket_cap=c.a2a_bucket_cap, **extra)
    fn = dist.batched_dist_mapsin_step if batched else dist.dist_mapsin_step
    return fn(bnd, st.patterns[0], keys, c.probe_cap, c.out_cap, axis,
              cfg.impl, shard_splits=splits, routing=cfg.routing,
              bucket_cap=c.a2a_bucket_cap, **extra)


def mesh_fingerprint(mesh, axis: str) -> tuple:
    """Hashable mesh identity for compile-cache keys: axis name + device
    ids in mesh order. Two meshes with the same fingerprint place the same
    shard on the same device, so a cascade compiled for one is valid for
    the other."""
    return (axis, tuple(mesh.axis_names),
            tuple(int(d.id) for d in np.ravel(mesh.devices)))


def _sharded_fn(plan: PhysicalPlan, cfg: ExecConfig, axis: str,
                splits_spo=None, splits_ops=None):
    steps = plan.steps

    def fn(keys_spo, keys_ops):
        keys_spo = keys_spo.reshape(-1)
        keys_ops = keys_ops.reshape(-1)
        keys_of = lambda pat, dom: (keys_spo if make_plan(pat, dom).index == 0
                                    else keys_ops)
        splits_of = lambda pat, dom: (splits_spo
                                      if make_plan(pat, dom).index == 0
                                      else splits_ops)
        bnd = ms.scan_pattern(steps[0].patterns[0],
                              keys_of(steps[0].patterns[0], ()),
                              steps[0].caps.out_cap, cfg.impl)
        for st in steps[1:]:
            c = st.caps
            if st.kind in ("mapsin", "multiway"):
                keys = keys_of(st.patterns[0], bnd.vars)
                bnd = apply_dist_step(
                    bnd, st, keys, splits_of(st.patterns[0], bnd.vars),
                    cfg, axis)
            else:
                for pat in st.patterns:
                    keys = keys_of(pat, ())  # relation scan: empty domain
                    bnd = rs.dist_reduce_step(bnd, pat, keys, c.scan_cap,
                                              c.bucket_cap, c.probe_cap,
                                              c.out_cap, axis, cfg.impl)
        return bnd.table, bnd.valid, bnd.overflow[None]
    return fn


def execute_sharded(store: TripleStore, query, mesh, mode: str = "mapsin",
                    cfg: ExecConfig = ExecConfig(), axis: str = "data",
                    routing: str | None = None, caps: Caps = Caps()):
    """Distributed execution under shard_map on `mesh` (store sharded on
    `axis`). `query` is a PhysicalPlan or a pattern sequence (compiled
    cost-based with num_shards = the mesh size, so a2a capacities are
    embedded from measurement at compile time — the planner subsumes the
    old tune_a2a_bucket_cap call). Probes are routed via the stored
    region splits: with cfg.routing == "broadcast" every shard sees every
    probe and answers only ranges intersecting its slice; with "a2a" each
    probe record is shipped point-to-point to exactly the intersecting
    shards (dist._dist_probe_a2a). `routing` overrides cfg.routing when
    given. Returns (table (S*cap, nv), valid, overflow (S,), vars)."""
    if routing is not None:
        cfg = dataclasses.replace(cfg, routing=routing)
    _check_plan_mode(query, mode)
    s = int(mesh.shape[axis])
    plan = as_plan(store, query, mode, cfg, caps, num_shards=s)
    if (cfg.routing == "a2a"
            and any(st.kind in ("mapsin", "multiway")
                    and st.caps.a2a_bucket_cap == 0
                    for st in plan.steps[1:])):
        # pre-compiled plan without embedded a2a caps: embed now, with the
        # drop-free bound read off the plan's OWN steps (caps=None) — the
        # `caps` argument only parameterizes pattern-list compilation
        from repro.core.planner import embed_a2a_caps
        plan = embed_a2a_caps(store, plan, None, s)
    # cache the jitted shard_map per (plan, cfg, mesh): a fresh closure
    # every call would defeat jax's jit cache (keyed on function identity)
    # and re-trace + re-compile on each execution
    ck = ("sharded", plan, cfg, axis, mesh)
    jitted = store.plan_cache.get(ck)
    if jitted is None:
        fn = _sharded_fn(plan, cfg, axis,
                         splits_spo=np.asarray(store.splits_spo),
                         splits_ops=np.asarray(store.splits_ops))
        sharded = shard_map(
            fn, mesh=mesh,
            in_specs=(P(axis, None), P(axis, None)),
            out_specs=(P(axis, None), P(axis), P(axis)),
            check_rep=False)
        jitted = jax.jit(sharded)
        store.plan_cache[ck] = jitted
    table, valid, overflow = jitted(store.keys_spo, store.keys_ops)
    return table, valid, overflow, plan.var_order


def rows_set(table, valid, n_vars: int) -> set[tuple[int, ...]]:
    """Materialize valid rows as a python set (host-side, for comparisons)."""
    t = np.asarray(table)[np.asarray(valid)]
    if n_vars == 0:
        return set([()] if len(t) else [])
    return set(map(tuple, t[:, :n_vars].tolist()))
