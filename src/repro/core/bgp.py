"""BGP planner + executor: variable-counting reorder, star-join grouping,
MAPSIN vs reduce-side execution, local or sharded, with traffic accounting.

Execution model (the fused probe engine, this module's layer of it):
the whole cascade — the first-pattern scan plus every `mapsin_step` /
`multiway_step` / reduce-side iteration — is compiled as ONE jitted
function per (plan, mode, config) and cached, so `execute_local` pays a
single dispatch per query instead of ~6 eager ops per step, and the
initial Bindings buffers are donated to the computation (active on
accelerator backends).  Host syncs (`int(count())` per step) happen only
on the opt-in `stats=` instrumentation path, which also measures the
probe->region fan-out that feeds `query_traffic_actual`'s routed model.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import distributed as dist
from repro.core import mapsin as ms
from repro.core import reduce_side as rs
from repro.core.plan import make_plan
from repro.core.rdf import Pattern
from repro.core.triple_store import TripleStore


@dataclasses.dataclass(frozen=True)
class ExecConfig:
    scan_cap: int = 1 << 14      # first-pattern scan capacity (per shard)
    probe_cap: int = 8           # matches per GET (per mapping)
    row_cap: int = 32            # row width for multiway single-GET
    out_cap: int = 1 << 14       # solution multiset capacity (per shard)
    bucket_cap: int = 1 << 12    # reduce-side shuffle bucket capacity
    impl: str = "jnp"            # jnp | pallas | pallas_interpret
    reorder: bool = True
    multiway: bool = True
    route_shards: int = 10       # hypothetical cluster for routed traffic
                                 # measurement (paper's 10-node setup)
    routing: str = "broadcast"   # dist_probe collective: broadcast | a2a
                                 # (a2a = point-to-point region routing)
    a2a_bucket_cap: int = 0      # per-destination probe bucket capacity for
                                 # routing="a2a"; 0 = auto-tune from the
                                 # measured probe->region fan-out
                                 # (tune_a2a_bucket_cap; static 2x-uniform
                                 # share for direct dist_probe callers),
                                 # out_cap = drop-free guarantee


@dataclasses.dataclass(frozen=True)
class Step:
    kind: str                    # scan | join | multiway
    patterns: tuple[Pattern, ...]


def pattern_cardinality(store: TripleStore, pat: Pattern) -> int:
    """Exact result count for a pattern's constant key prefix — one binary
    search pair against the store index. This is the statistics-based
    selectivity the paper's §7 lists as future work; the sorted composite-key
    store makes it free. Memoized per store (planning stays off the timed
    path when the same query re-executes)."""
    ck = ("card", pat)
    if ck in store.plan_cache:
        return store.plan_cache[ck]
    plan = make_plan(pat, ())
    if not plan.prefix:
        n = store.n_triples
    else:
        from repro.core.plan import probe_ranges
        empty = jnp.zeros((1, 0), jnp.int32)
        lo, hi = probe_ranges(plan, empty)
        keys = _host_keys(store, plan.index)
        n = int(np.searchsorted(keys, np.asarray(hi)[0])
                - np.searchsorted(keys, np.asarray(lo)[0]))
    store.plan_cache[ck] = n
    return n


def order_patterns(patterns: Sequence[Pattern], reorder: bool = True,
                   store: TripleStore | None = None):
    """Variable-counting heuristic (paper §4.2): most selective first, then
    greedily prefer patterns connected to the bound domain. With a store,
    ties break on measured prefix-range cardinality (beyond-paper)."""
    pats = list(patterns)
    if not reorder:
        return pats

    def rank(p: Pattern):
        base = p.selectivity_rank()
        if store is not None:
            return base + (pattern_cardinality(store, p),)
        return base

    pats_sorted = sorted(pats, key=rank)
    out = [pats_sorted.pop(0)]
    domain = set(out[0].variables)
    while pats_sorted:
        connected = [p for p in pats_sorted if set(p.variables) & domain]
        nxt = min(connected or pats_sorted, key=rank)
        pats_sorted.remove(nxt)
        out.append(nxt)
        domain |= set(nxt.variables)
    return out


def plan_steps(patterns: Sequence[Pattern], cfg: ExecConfig,
               store: TripleStore | None = None) -> list[Step]:
    if store is not None:
        sk = ("steps", tuple(patterns), cfg)
        if sk not in store.plan_cache:
            store.plan_cache[sk] = _plan_steps_uncached(patterns, cfg, store)
        return list(store.plan_cache[sk])
    return _plan_steps_uncached(patterns, cfg, store)


def _plan_steps_uncached(patterns: Sequence[Pattern], cfg: ExecConfig,
                         store: TripleStore | None = None) -> list[Step]:
    ordered = order_patterns(patterns, cfg.reorder, store)
    steps: list[Step] = [Step("scan", (ordered[0],))]
    domain: list[str] = list(ordered[0].variables)
    i = 1
    while i < len(ordered):
        group = [ordered[i]]
        if cfg.multiway:
            plan_i = make_plan(ordered[i], domain)
            new_vars = set(plan_i.out_var_names)
            j = i + 1
            while j < len(ordered) and len(plan_i.prefix) >= 1:
                cand = make_plan(ordered[j], domain)
                same_row = (cand.index == plan_i.index and
                            len(cand.prefix) >= 1 and
                            cand.prefix[0] == plan_i.prefix[0])
                fresh = not (set(cand.out_var_names) & new_vars)
                uses_new = bool(set(ordered[j].variables) & new_vars)
                if not (same_row and fresh and not uses_new):
                    break
                group.append(ordered[j])
                new_vars |= set(cand.out_var_names)
                j += 1
        if len(group) > 1:
            steps.append(Step("multiway", tuple(group)))
        else:
            steps.append(Step("join", (group[0],)))
        for g in group:
            for v in g.variables:
                if v not in domain:
                    domain.append(v)
        i += len(group)
    return steps


# ---------------------------------------------------------------------------
# Traffic accounting (bytes shipped by the collectives; static formulas)
# ---------------------------------------------------------------------------


def step_traffic_bytes(step: Step, mode: str, cfg: ExecConfig, num_shards: int,
                       n_vars_before: int) -> int:
    """Global bytes crossing the interconnect for one step (padding included).

    Modes:
      mapsin         — the implemented broadcast-GET: probe keys are
                       all-gathered (correct for arbitrarily fat rows), match
                       counts all-gathered, matches psum_scattered home.
                       Pays O(S) on the key/count legs — fine for pods,
                       quantified so §Perf can show the routed win.
      mapsin_routed  — the production point-to-point GET (DESIGN.md §2):
                       each probe travels to its owner shard once (a2a) and
                       its matches travel back once. O(B) — the paper's RPC.
                       The record is two keys + origin bookkeeping; the
                       residual filters never cross the network (applied by
                       the origin shard after the round trip — see
                       _dist_probe_a2a).
      reduce         — shuffle BOTH relations (repartition join).
    """
    s, b = num_shards, cfg.out_cap
    if s == 1 or step.kind == "scan":
        return 0
    cap = cfg.row_cap if step.kind == "multiway" else cfg.probe_cap
    if mode == "mapsin":
        keys = s * b * (8 + 8 + 24) * (s - 1)          # all_gather lo/hi/filters
        counts = s * (s * b) * 4 * (s - 1)             # all_gather counts
        matches = s * (s * b) * cap * 8                # psum_scatter ring pass
        return keys + counts + matches
    if mode == "mapsin_routed":
        keys = s * b * (8 + 8 + 4)                     # a2a probe records
        matches = s * b * cap * 8                      # a2a matches home
        return keys + matches
    # reduce-side: shuffle Omega and the scanned relation in full
    nv_left = n_vars_before
    per_rel = s * s * cfg.bucket_cap * 4               # rows x int32 cols
    rounds = len(step.patterns)
    return rounds * (per_rel * (nv_left + 3) + per_rel)  # + validity bytes


# ---------------------------------------------------------------------------
# Executors
# ---------------------------------------------------------------------------


def _cascade_body(steps: tuple, mode: str, cfg: ExecConfig):
    """The whole-cascade computation: (keys_spo, keys_ops, scratch) -> Bindings.

    One traced function per (plan, mode, cfg): every scan/join/multiway
    iteration fuses into a single XLA computation, so repeated execution
    pays one dispatch and zero per-step host syncs. `scratch` is the
    zeroed initial Bindings, donated on backends that support donation.
    """
    first = steps[0].patterns[0]
    first_vars = make_plan(first, ()).out_var_names

    def fn(keys_spo, keys_ops, scratch):
        keys_of = lambda pat, dom: (keys_spo if make_plan(pat, dom).index == 0
                                    else keys_ops)
        bnd = ms.scan_pattern(first, keys_of(first, ()), cfg.out_cap,
                              cfg.impl, scratch=scratch)
        for st in steps[1:]:
            if mode == "mapsin":
                keys = keys_of(st.patterns[0], bnd.vars)
                if st.kind == "multiway":
                    bnd = ms.multiway_step(bnd, st.patterns, keys, cfg.row_cap,
                                           cfg.out_cap, cfg.impl)
                else:
                    bnd = ms.mapsin_step(bnd, st.patterns[0], keys,
                                         cfg.probe_cap, cfg.out_cap, cfg.impl)
            else:
                for pat in st.patterns:  # reduce-side: relation scanned fresh
                    bnd = rs.local_reduce_step(bnd, pat, keys_of(pat, ()),
                                               cfg.scan_cap, cfg.probe_cap,
                                               cfg.out_cap, cfg.impl)
        return bnd

    return fn, first_vars


def _compiled_cascade(store: TripleStore, steps: tuple, mode: str,
                      cfg: ExecConfig):
    key = ("cascade", steps, mode, cfg)
    hit = store.plan_cache.get(key)
    if hit is None:
        fn, first_vars = _cascade_body(steps, mode, cfg)
        donate = (2,) if jax.default_backend() in ("tpu", "gpu") else ()
        hit = (jax.jit(fn, donate_argnums=donate), first_vars)
        store.plan_cache[key] = hit
    return hit


def execute_local(store: TripleStore, patterns: Sequence[Pattern],
                  mode: str = "mapsin", cfg: ExecConfig = ExecConfig(),
                  stats: list | None = None):
    """Single-shard execution (functional reference; also the oracle's peer).

    The default path runs the cached whole-cascade jit — no per-step
    dispatch, no host syncs in the timed region. When `stats` is a list
    (opt-in instrumentation, off the hot path), the cascade runs stepwise
    and appends per-step dicts with ACTUAL row counts plus the measured
    probe->region fan-out — feeds the measured traffic model in
    query_traffic_actual (the paper's network metric)."""
    steps = tuple(plan_steps(patterns, cfg, store))
    if stats is not None:
        return _execute_local_instrumented(store, steps, mode, cfg, stats)
    jitted, first_vars = _compiled_cascade(store, steps, mode, cfg)
    scratch = ms.Bindings.empty(first_vars, cfg.out_cap)
    return jitted(store.flat_keys(0), store.flat_keys(1), scratch)


def _host_keys(store: TripleStore, index: int) -> np.ndarray:
    """Host-side copy of one flattened index (one device->host transfer)."""
    ck = ("np_keys", index)
    if ck not in store.plan_cache:
        store.plan_cache[ck] = np.asarray(store.flat_keys(index))
    return store.plan_cache[ck]


def _route_splits(store: TripleStore, index: int, s: int) -> np.ndarray:
    """Region boundaries for a hypothetical `s`-shard layout of the index:
    the stored splits when the store is already sharded that way, otherwise
    exactly what build_store would pick (same _shard_sorted rule)."""
    if s == store.num_shards:
        return np.asarray(store.splits(index))
    ck = ("route_splits", index, s)
    if ck not in store.plan_cache:
        from repro.core.triple_store import _shard_sorted
        keys = _host_keys(store, index)
        keys = keys[keys < np.iinfo(np.int64).max]
        _, splits, _ = _shard_sorted(keys, s)
        store.plan_cache[ck] = splits
    return store.plan_cache[ck]


def _probe_fanout(store: TripleStore, plan, bnd: ms.Bindings, s: int,
                  whole_row: bool = False) -> tuple[int, int, int]:
    """Measured routing fan-out if each probe were routed only to shards
    whose key range it intersects — the paper's region-server GET, vs the
    broadcast's n_in * S. Returns (total deliveries, max per-region load,
    max range-entry count per probe); the per-region max sizes the a2a
    per-destination probe buckets and the per-probe max sizes the answer
    return leg (tune_a2a_bucket_cap)."""
    from repro.core.plan import probe_ranges, row_range
    lo, hi = (row_range if whole_row else probe_ranges)(plan, bnd.table)
    lo, hi = np.asarray(lo), np.asarray(hi)
    valid = np.asarray(bnd.valid)
    splits = _route_splits(store, plan.index, s)
    from repro.core.triple_store import range_intersects_region
    hits = range_intersects_region(lo[:, None], hi[:, None],
                                   splits[None, :-1], splits[None, 1:])
    per_region = hits[valid].sum(axis=0)
    keys = _host_keys(store, plan.index)
    lens = (np.searchsorted(keys, hi[valid])
            - np.searchsorted(keys, lo[valid]))
    return (int(per_region.sum()), int(per_region.max(initial=0)),
            int(lens.max(initial=0)))


def _execute_local_instrumented(store: TripleStore, steps: tuple, mode: str,
                                cfg: ExecConfig, stats: list):
    keys_of = lambda pat, dom: store.flat_keys(make_plan(pat, dom).index)
    s_route = cfg.route_shards
    bnd = ms.scan_pattern(steps[0].patterns[0],
                          keys_of(steps[0].patterns[0], ()), cfg.out_cap,
                          cfg.impl)
    stats.append({"kind": "scan", "n_in": 0, "n_out": int(bnd.count()),
                  "nv": len(bnd.vars), "relation": int(bnd.count()),
                  "n_patterns": 1})
    for st in steps[1:]:
        n_in, nv_in = int(bnd.count()), len(bnd.vars)
        deliveries = max_region = probe_len = 0
        if mode == "mapsin":
            keys = keys_of(st.patterns[0], bnd.vars)
            plan0 = make_plan(st.patterns[0], bnd.vars)
            if st.kind == "multiway":
                deliveries, max_region, probe_len = _probe_fanout(
                    store, plan0, bnd, s_route, whole_row=True)
                bnd = ms.multiway_step(bnd, st.patterns, keys, cfg.row_cap,
                                       cfg.out_cap, cfg.impl)
            else:
                deliveries, max_region, probe_len = _probe_fanout(
                    store, plan0, bnd, s_route)
                bnd = ms.mapsin_step(bnd, st.patterns[0], keys, cfg.probe_cap,
                                     cfg.out_cap, cfg.impl)
        else:
            for pat in st.patterns:  # reduce-side has no multiway shortcut here
                # the relation is scanned fresh (empty domain -> scan index)
                keys = keys_of(pat, ())
                bnd = rs.local_reduce_step(bnd, pat, keys, cfg.scan_cap,
                                           cfg.probe_cap, cfg.out_cap, cfg.impl)
        rel = 0
        for pat in st.patterns:
            r = ms.scan_pattern(pat, keys_of(pat, ()), cfg.scan_cap, cfg.impl)
            rel += int(r.count())
        stats.append({"kind": st.kind, "n_in": n_in,
                      "n_out": int(bnd.count()), "nv": nv_in,
                      "relation": rel, "n_patterns": len(st.patterns),
                      "deliveries": deliveries, "route_shards": s_route,
                      "deliveries_max_region": max_region,
                      "probe_len_max": probe_len})
    return bnd


_MISSING = object()   # plan-cache sentinel (a cached value may be None)


def tune_a2a_bucket_cap(store: TripleStore, patterns: Sequence[Pattern],
                        cfg: ExecConfig, num_shards: int) -> int:
    """Measured per-destination probe-bucket capacity for routing="a2a".

    Runs the query once instrumented (host-side, cached per
    (patterns, cfg, S) in the store's plan cache) and sizes the bucket to
    the MAX per-region probe load any join step actually delivers —
    exact for this (query, store, splits) since the fan-out accounting
    and the a2a dispatch share range_intersects_region and the same
    region boundaries, PROVIDED the tuning run saw the full binding
    multiset. Replaces the static 2x-uniform-share default
    (auto_bucket_cap), which over-allocates selective queries by orders
    of magnitude and under-allocates heavy skew. `out_cap` stays the
    drop-free fallback: it bounds the result (a shard never routes more
    probes than it has bindings) and is returned when nothing was
    measurable (a single-step scan that never probes) or when the tuning
    run OVERFLOWED — the sharded run keeps out_cap rows PER SHARD, so a
    truncated single-store measurement would under-size the buckets and
    drop probes the static default delivered."""
    ck = ("a2a_tune", tuple(patterns), cfg, num_shards)
    sk = ("a2a_tune_steps",) + ck[1:]
    hit = store.plan_cache.get(ck)
    # early-return only when the companion step-caps entry is also still
    # resident (both are re-read so the LRU refreshes them together): the
    # two keys can otherwise diverge under eviction pressure, leaving
    # tuned_step_answer_caps permanently None for a still-cached cap
    if hit is not None and store.plan_cache.get(sk, _MISSING) is not _MISSING:
        return hit
    stats: list = []
    tune_cfg = dataclasses.replace(cfg, route_shards=num_shards,
                                   routing="broadcast", a2a_bucket_cap=0)
    bnd = execute_local(store, patterns, "mapsin", tune_cfg, stats=stats)
    loads = [st["deliveries_max_region"] for st in stats
             if st["kind"] != "scan" and "deliveries_max_region" in st]
    overflowed = int(np.asarray(bnd.overflow)) > 0
    if not loads or overflowed:
        cap = cfg.out_cap
    else:
        cap = min(max(max(loads), 8), cfg.out_cap)
    # per-join-step answer caps ride along from the same measured run: the
    # max range-entry count any probe of that step actually covers bounds
    # the a2a return leg (min'd with the configured cap — never looser).
    # None on overflow: a truncated tuning run under-measures (same
    # reasoning as the bucket fallback above).
    if overflowed:
        step_caps = None
    else:
        step_caps = tuple(
            min(max(st.get("probe_len_max", 0), 1),
                cfg.row_cap if st["kind"] == "multiway" else cfg.probe_cap)
            for st in stats if st["kind"] != "scan")
    store.plan_cache[sk] = step_caps
    store.plan_cache[ck] = cap
    return cap


def tuned_step_answer_caps(store: TripleStore, patterns: Sequence[Pattern],
                           cfg: ExecConfig, num_shards: int):
    """Per-join-step measured answer caps for routing="a2a" (the a2a
    return leg ships `cap` key slots per routed probe — right-sizing it
    from the measured max range length is what keeps batched serving's
    match traffic proportional to actual matches). Computed by the same
    cached tuning run as tune_a2a_bucket_cap; None when nothing reliable
    was measured (overflowed tuning run) — callers fall back to the
    configured caps."""
    ck = ("a2a_tune_steps", tuple(patterns), cfg, num_shards)
    if ck not in store.plan_cache:
        tune_a2a_bucket_cap(store, patterns, cfg, num_shards)
    return store.plan_cache.get(ck)


def query_traffic_actual(stats: list, mode: str, num_shards: int,
                         n_triples: int = 0) -> dict:
    """Data-movement bytes from ACTUAL row counts (vs the static-capacity
    model in query_traffic). Two components, mirroring the paper's setting:

    network — what crosses the interconnect per join step:
      mapsin_routed — split-aware routing: each input mapping's probe
                      record (20 B: lo/hi keys + origin; the residual
                      filters stay on the origin shard since PR 4) travels
                      once per REGION its key range intersects — the
                      MEASURED fan-out recorded by the instrumented
                      executor ("deliveries"; ~1 for point probes, >1 only
                      for fat rows spanning region boundaries) — and each
                      match comes back once (12 B triple);
      mapsin        — broadcast-GET: 44 B probe records (lo/hi + filters +
                      origin) x (S-1), matches once;
      reduce        — Omega + the (already filtered) relation are shuffled.

    scanned — storage bytes read to produce the step's input:
      reduce        — HDFS has NO index: every pattern forces a full pass
                      over the dataset in the map phase (the dominant cost
                      the paper measures for selective queries);
      mapsin        — index GETs: ~log2(N) binary-search touches per probe
                      plus the matched entries only.
    """
    import math
    s = num_shards
    net = 0
    scanned = 0
    routed = broadcast = 0                 # probe records: routed vs x(S-1)
    logn = max(math.ceil(math.log2(max(n_triples, 2))), 1)
    for st in stats:
        rounds = 1 if st["kind"] == "multiway" else st["n_patterns"]
        if st["kind"] == "scan":
            if mode == "reduce":
                scanned += n_triples * 8          # full pass, no index
            else:
                scanned += st["n_out"] * 8 + logn * 8  # index range scan
            continue
        rec_routed, rec_bcast, match_b = 20, 44, 12
        deliv = (st["deliveries"] if st.get("route_shards") == s
                 and "deliveries" in st else st["n_in"])
        routed += deliv * rec_routed * rounds
        broadcast += st["n_in"] * rec_bcast * (s - 1) * rounds
        if mode == "mapsin_routed":
            if s > 1:
                net += deliv * rec_routed * rounds + st["n_out"] * match_b
            scanned += st["n_in"] * rounds * logn * 8 + st["n_out"] * 8
        elif mode == "mapsin":
            if s > 1:
                net += (st["n_in"] * rec_bcast * (s - 1) * rounds
                        + st["n_out"] * match_b)
            scanned += st["n_in"] * rounds * logn * 8 + st["n_out"] * 8
        else:  # reduce-side
            row_l = st["nv"] * 4 + 4
            if s > 1:
                net += st["n_patterns"] * (st["n_in"] * row_l
                                           + st["relation"] * 16)
            scanned += st["n_patterns"] * n_triples * 8
    return {"network": net, "scanned": scanned, "total": net + scanned,
            "probe_bytes_routed": routed, "probe_bytes_broadcast": broadcast}


def apply_dist_step(bnd: ms.Bindings, st: Step, keys, splits,
                    cfg: ExecConfig, axis: str,
                    batched: bool = False) -> ms.Bindings:
    """One distributed MAPSIN cascade step (join or multiway star) — the
    shared dispatch behind execute_sharded's per-shard body and the serving
    engine's batched template cascade (`batched=True` expects Bindings with
    a leading query axis and routes the whole batch through ONE collective
    round per step; see core/distributed.py)."""
    if st.kind == "multiway":
        fn = (dist.batched_dist_multiway_step if batched
              else dist.dist_multiway_step)
        return fn(bnd, st.patterns, keys, cfg.row_cap, cfg.out_cap, axis,
                  cfg.impl, shard_splits=splits, routing=cfg.routing,
                  bucket_cap=cfg.a2a_bucket_cap)
    fn = dist.batched_dist_mapsin_step if batched else dist.dist_mapsin_step
    return fn(bnd, st.patterns[0], keys, cfg.probe_cap, cfg.out_cap, axis,
              cfg.impl, shard_splits=splits, routing=cfg.routing,
              bucket_cap=cfg.a2a_bucket_cap)


def mesh_fingerprint(mesh, axis: str) -> tuple:
    """Hashable mesh identity for compile-cache keys: axis name + device
    ids in mesh order. Two meshes with the same fingerprint place the same
    shard on the same device, so a cascade compiled for one is valid for
    the other."""
    return (axis, tuple(mesh.axis_names),
            tuple(int(d.id) for d in np.ravel(mesh.devices)))


def _sharded_fn(steps: list[Step], mode: str, cfg: ExecConfig, axis: str,
                splits_spo=None, splits_ops=None):
    def fn(keys_spo, keys_ops):
        keys_spo = keys_spo.reshape(-1)
        keys_ops = keys_ops.reshape(-1)
        keys_of = lambda pat, dom: (keys_spo if make_plan(pat, dom).index == 0
                                    else keys_ops)
        splits_of = lambda pat, dom: (splits_spo
                                      if make_plan(pat, dom).index == 0
                                      else splits_ops)
        bnd = ms.scan_pattern(steps[0].patterns[0],
                              keys_of(steps[0].patterns[0], ()), cfg.out_cap,
                              cfg.impl)
        for st in steps[1:]:
            if mode == "mapsin":
                keys = keys_of(st.patterns[0], bnd.vars)
                bnd = apply_dist_step(
                    bnd, st, keys, splits_of(st.patterns[0], bnd.vars),
                    cfg, axis)
            else:
                for pat in st.patterns:
                    keys = keys_of(pat, ())  # relation scan: empty domain
                    bnd = rs.dist_reduce_step(bnd, pat, keys, cfg.scan_cap,
                                              cfg.bucket_cap, cfg.probe_cap,
                                              cfg.out_cap, axis, cfg.impl)
        return bnd.table, bnd.valid, bnd.overflow[None]
    return fn


def execute_sharded(store: TripleStore, patterns: Sequence[Pattern],
                    mesh, mode: str = "mapsin",
                    cfg: ExecConfig = ExecConfig(), axis: str = "data",
                    routing: str | None = None):
    """Distributed execution under shard_map on `mesh` (store sharded on
    `axis`). Probes are routed via the stored region splits: with
    cfg.routing == "broadcast" every shard sees every probe and answers
    only ranges intersecting its slice; with "a2a" each probe record is
    shipped point-to-point to exactly the intersecting shards
    (dist._dist_probe_a2a). `routing` overrides cfg.routing when given.
    Returns (table (S*cap, nv), valid, overflow (S,), vars).

    With routing == "a2a" and cfg.a2a_bucket_cap == 0 the per-destination
    probe buckets are auto-tuned from the MEASURED probe->region fan-out
    (tune_a2a_bucket_cap) instead of the static 2x-uniform-share
    heuristic — the ROADMAP open item; pass a positive a2a_bucket_cap
    (e.g. out_cap for the drop-free guarantee) to override."""
    if routing is not None:
        cfg = dataclasses.replace(cfg, routing=routing)
    if cfg.routing == "a2a" and cfg.a2a_bucket_cap == 0 and mode == "mapsin":
        tuned = tune_a2a_bucket_cap(store, patterns, cfg,
                                    int(mesh.shape[axis]))
        cfg = dataclasses.replace(cfg, a2a_bucket_cap=tuned)
    steps = plan_steps(patterns, cfg, store)
    # derive final var order (static)
    domain: list[str] = []
    for st in steps:
        for pat in st.patterns:
            plan = make_plan(pat, domain)
            domain.extend(plan.out_var_names)
    # cache the jitted shard_map per (plan, mode, cfg, mesh): a fresh
    # closure every call would defeat jax's jit cache (keyed on function
    # identity) and re-trace + re-compile on each execution
    ck = ("sharded", tuple(steps), mode, cfg, axis, mesh)
    jitted = store.plan_cache.get(ck)
    if jitted is None:
        fn = _sharded_fn(steps, mode, cfg, axis,
                         splits_spo=np.asarray(store.splits_spo),
                         splits_ops=np.asarray(store.splits_ops))
        sharded = shard_map(
            fn, mesh=mesh,
            in_specs=(P(axis, None), P(axis, None)),
            out_specs=(P(axis, None), P(axis), P(axis)),
            check_rep=False)
        jitted = jax.jit(sharded)
        store.plan_cache[ck] = jitted
    table, valid, overflow = jitted(store.keys_spo, store.keys_ops)
    return table, valid, overflow, tuple(domain)


def query_traffic(patterns: Sequence[Pattern], mode: str, cfg: ExecConfig,
                  num_shards: int) -> int:
    """Total modeled interconnect bytes for a query (paper's network metric)."""
    steps = plan_steps(patterns, cfg)
    domain: list[str] = []
    total = 0
    for st in steps:
        total += step_traffic_bytes(st, mode, cfg, num_shards, len(domain))
        for pat in st.patterns:
            plan = make_plan(pat, domain)
            domain.extend(plan.out_var_names)
    return total


def rows_set(table, valid, n_vars: int) -> set[tuple[int, ...]]:
    """Materialize valid rows as a python set (host-side, for comparisons)."""
    t = np.asarray(table)[np.asarray(valid)]
    if n_vars == 0:
        return set([()] if len(t) else [])
    return set(map(tuple, t[:, :n_vars].tolist()))
