"""Sharded sorted triple store — the HBase analogue (DESIGN.md §2).

Two indexes mirror the paper's two-table schema:
  T_spo — composite keys sorted by (s, p, o)   [row key = subject]
  T_ops — composite keys sorted by (o, p, s)   [row key = object]

Each index is range-partitioned into `num_shards` equal slices by sampled
quantiles of the *full composite key* (region boundaries). A fat row (the
paper's `rdf:type` problem) therefore legally spans shards — probes that
cover it fan out to every intersecting shard, which is exactly the paper's
compound-rowkey fix generalized: no single machine ever owns a whole class.

Shards are padded to equal length with INF keys so every per-shard array is
statically shaped (TPU requirement).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict

import jax.numpy as jnp
import numpy as np

from repro.common import ceil_div
from repro.core.rdf import INF_KEY, pack3

SPO, OPS = 0, 1  # index ids (paper Table 3 chooses between them per pattern)

PLAN_CACHE_SIZE = 512  # default plan_cache bound (entries, not bytes)


class LRUCache(OrderedDict):
    """Dict with least-recently-used eviction — bounds the per-store
    plan/compile cache (and the serving layer's per-engine compile cache)
    so a many-tenant query stream can't grow host memory forever.

    Reads (`[]` / `get`) refresh recency; writes evict the coldest entry
    once `maxsize` is exceeded. Evicting a compiled cascade only costs a
    re-trace on the next miss — never correctness.
    """

    def __init__(self, maxsize: int = PLAN_CACHE_SIZE):
        super().__init__()
        if maxsize < 1:
            raise ValueError("LRUCache needs maxsize >= 1")
        self.maxsize = maxsize

    def __getitem__(self, key):
        val = super().__getitem__(key)
        self.move_to_end(key)
        return val

    def get(self, key, default=None):
        if key in self:
            return self[key]
        return default

    def __setitem__(self, key, val):
        super().__setitem__(key, val)
        self.move_to_end(key)
        while len(self) > self.maxsize:
            del self[next(iter(self))]    # coldest (front) entry


@dataclasses.dataclass
class TripleStore:
    # (num_shards, shard_cap) int64, sorted ascending within & across shards
    keys_spo: jnp.ndarray
    keys_ops: jnp.ndarray
    # (num_shards + 1,) int64 region boundaries (splitters[0] = -1)
    splits_spo: jnp.ndarray
    splits_ops: jnp.ndarray
    counts_spo: jnp.ndarray  # (num_shards,) valid entries per shard
    counts_ops: jnp.ndarray
    n_triples: int
    # monotonically increasing mutation counter (DESIGN.md §9): 0 for a
    # build-once store, bumped by bump_version() on EVERY applied mutation
    # batch (ingest / flush / recovery replay). It is part of layout_key,
    # so every compile/plan/stat cache keyed on the store misses after a
    # mutation instead of serving rows from a pre-ingest world.
    store_version: int = 0
    # host-side memo: flattened keys, measured cardinalities, ordered step
    # plans and compiled cascades keyed by (patterns, cfg) — keeps repeated
    # query execution off the eager-dispatch path (core/bgp.py). LRU-bounded:
    # under a many-tenant query stream the per-(patterns, cfg) entries would
    # otherwise accumulate forever; hot entries stay resident, cold ones
    # re-trace on their next use.
    plan_cache: LRUCache = dataclasses.field(
        default_factory=LRUCache, repr=False, compare=False)

    @property
    def num_shards(self) -> int:
        return self.keys_spo.shape[0]

    @property
    def shard_cap(self) -> int:
        return self.keys_spo.shape[1]

    def keys(self, index: int) -> jnp.ndarray:
        return self.keys_spo if index == SPO else self.keys_ops

    def flat_keys(self, index: int) -> jnp.ndarray:
        key = ("flat_keys", index)
        if key not in self.plan_cache:
            self.plan_cache[key] = self.keys(index).reshape(-1)
        return self.plan_cache[key]

    def splits(self, index: int) -> jnp.ndarray:
        return self.splits_spo if index == SPO else self.splits_ops

    @property
    def layout_key(self) -> tuple:
        """Hashable shard-layout identity: ``store_version`` + shard shape
        + the actual region boundaries of both indexes. A compiled cascade
        bakes the splits in as constants — and a compiled PLAN bakes in
        measured statistics — so any compile cache keyed on the store MUST
        include this: rebuilding, resharding, or MUTATING the store (live
        ingest bumps store_version even when the boundaries happen to
        survive) changes the key and can never reuse a stale compilation
        against post-ingest data."""
        ck = ("layout_key",)
        if ck not in self.plan_cache:
            self.plan_cache[ck] = (
                self.store_version,
                self.num_shards, self.shard_cap, self.n_triples,
                tuple(int(x) for x in np.asarray(self.splits_spo)),
                tuple(int(x) for x in np.asarray(self.splits_ops)))
        return self.plan_cache[ck]

    def bump_version(self) -> int:
        """Mutation barrier (DESIGN.md §9): advance ``store_version`` and
        drop EVERY memoized artifact in ``plan_cache`` — flattened key
        views, host key copies, ``relation_stats``/``pattern_cardinality``
        statistics, compiled plans with embedded measured capacities, and
        compiled cascades. Anything derived from pre-mutation key values
        is stale after an ingest: stale STATISTICS would only mis-price
        operators (results stay exact — caps truncation is surfaced and
        escalated, never silent), but a compiled sharded cascade bakes
        region splits in as constants and a cached plan bakes in measured
        a2a capacities, so wholesale invalidation is the only state a
        mutation can leave behind that is correct by construction."""
        self.store_version += 1
        self.plan_cache.clear()
        return self.store_version

    def storage_bytes(self) -> int:
        return int(self.keys_spo.size + self.keys_ops.size) * 8


def range_intersects_region(lo, hi, excl_lo, incl_hi):
    """Does probe range [lo, hi) intersect region (excl_lo, incl_hi]?

    Exact, not heuristic, because store keys are unique and globally
    sorted: the range misses the region iff lo > incl_hi or
    hi <= excl_lo + 1. The single source of truth for both the routed
    dist_probe mask (core/distributed.py) and the measured fan-out
    accounting (core/bgp.py). Works elementwise on numpy or jnp arrays.
    """
    return (lo <= incl_hi) & (hi > excl_lo + 1)


def _shard_sorted(keys: np.ndarray, num_shards: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Split a sorted key array into equal shards; return (padded, splits, counts)."""
    n = len(keys)
    cap = max(ceil_div(n, num_shards), 1)
    padded = np.full((num_shards, cap), INF_KEY, np.int64)
    splits = np.empty(num_shards + 1, np.int64)
    counts = np.zeros(num_shards, np.int64)
    splits[0] = np.int64(-1)
    for k in range(num_shards):
        lo, hi = k * cap, min((k + 1) * cap, n)
        cnt = max(hi - lo, 0)
        if cnt > 0:
            padded[k, :cnt] = keys[lo:hi]
        counts[k] = cnt
        splits[k + 1] = keys[hi - 1] if cnt > 0 else splits[k]
    splits[num_shards] = INF_KEY
    return padded, splits, counts


def build_store(triples: np.ndarray, num_shards: int = 1) -> TripleStore:
    """triples: (N, 3) int32. Bulk load (the paper's Table 4 operation)."""
    s, p, o = triples[:, 0], triples[:, 1], triples[:, 2]
    k_spo = np.sort(pack3(s, p, o))
    k_ops = np.sort(pack3(o, p, s))
    if len(k_spo) and k_spo[-1] == INF_KEY:
        # (MAX_ID, MAX_ID, MAX_ID) packs to the INF_KEY padding sentinel:
        # it would be indistinguishable from padding and unfindable (every
        # probe range's exclusive hi saturates at INF_KEY). The Dictionary
        # reserves id MAX_ID so encoded data can never hit this.
        raise ValueError("triple (MAX_ID, MAX_ID, MAX_ID) packs to the "
                         "INF_KEY sentinel and cannot be stored")
    # dedup (RDF set semantics)
    k_spo = np.unique(k_spo)
    k_ops = np.unique(k_ops)
    spo, sp_splits, sp_counts = _shard_sorted(k_spo, num_shards)
    ops, op_splits, op_counts = _shard_sorted(k_ops, num_shards)
    return TripleStore(
        keys_spo=jnp.asarray(spo), keys_ops=jnp.asarray(ops),
        splits_spo=jnp.asarray(sp_splits), splits_ops=jnp.asarray(op_splits),
        counts_spo=jnp.asarray(sp_counts), counts_ops=jnp.asarray(op_counts),
        n_triples=int(len(k_spo)),
    )
