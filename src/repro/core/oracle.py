"""Brute-force BGP oracle (pure python/numpy) — ground truth for tests."""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core.rdf import Pattern, is_var


def match_pattern(triples: np.ndarray, pattern: Pattern,
                  binding: dict[str, int]):
    """Yield extended bindings for one pattern given a partial binding."""
    for s, p, o in triples:
        b = dict(binding)
        ok = True
        for term, val in ((pattern.s, int(s)), (pattern.p, int(p)),
                          (pattern.o, int(o))):
            if is_var(term):
                if term in b and b[term] != val:
                    ok = False
                    break
                b[term] = val
            elif int(term) != val:
                ok = False
                break
        if ok:
            yield b


def execute_oracle(triples: np.ndarray, patterns: Sequence[Pattern],
                   var_order: Sequence[str] | None = None):
    """Full nested-loop evaluation; returns (set of rows, var order)."""
    triples = np.unique(triples, axis=0)
    bindings: list[dict[str, int]] = [{}]
    for pat in patterns:
        bindings = [b2 for b in bindings for b2 in match_pattern(triples, pat, b)]
    if var_order is None:
        var_order = []
        for pat in patterns:
            for v in pat.variables:
                if v not in var_order:
                    var_order.append(v)
    rows = set(tuple(b[v] for v in var_order) for b in bindings)
    return rows, tuple(var_order)
