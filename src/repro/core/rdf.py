"""RDF terms, triple patterns and composite-key packing.

Terms are dictionary-encoded to int32 ids (< 2^21). A triple (s, p, o) packs
into one int64 composite key per index order — the sorted composite key IS
the index (HBase row key + column qualifier in one word), so a GET/SCAN is a
binary-search range over one int64 array and the payload is recovered by
unpacking (no extra storage: the paper's space-efficiency point, sharpened).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Union

import jax.numpy as jnp
import numpy as np

BITS = 21
MAX_ID = (1 << BITS) - 1
INF_KEY = np.iinfo(np.int64).max

Term = Union[str, int]  # "?x" variable, otherwise constant id (int)


def is_var(t: Term) -> bool:
    return isinstance(t, str)


def pack3(a, b, c):
    """Composite key (works on numpy or jnp arrays)."""
    m = jnp if isinstance(a, jnp.ndarray) else np
    a = m.asarray(a, m.int64)
    b = m.asarray(b, m.int64)
    c = m.asarray(c, m.int64)
    return (a << (2 * BITS)) | (b << BITS) | c


def unpack3(key):
    m = jnp if isinstance(key, jnp.ndarray) else np
    key = m.asarray(key, m.int64)
    mask = m.int64(MAX_ID)
    return ((key >> (2 * BITS)) & mask, (key >> BITS) & mask, key & mask)


@dataclasses.dataclass(frozen=True)
class Pattern:
    """SPARQL triple pattern; strings (conventionally '?x') are variables."""
    s: Term
    p: Term
    o: Term

    @property
    def terms(self) -> tuple[Term, Term, Term]:
        return (self.s, self.p, self.o)

    @property
    def variables(self) -> tuple[str, ...]:
        seen: list[str] = []
        for t in self.terms:
            if is_var(t) and t not in seen:
                seen.append(t)
        return tuple(seen)

    def n_vars(self) -> int:
        return len(self.variables)

    def selectivity_rank(self) -> tuple:
        """Variable-counting heuristic (paper §4.2 / [30]): fewer variables
        first; among equals, bound subject > bound object > bound predicate."""
        bound_s = 0 if is_var(self.s) else 1
        bound_p = 0 if is_var(self.p) else 1
        bound_o = 0 if is_var(self.o) else 1
        return (-(bound_s + bound_p + bound_o),
                -(4 * bound_s + 2 * bound_o + bound_p))


class Dictionary:
    """Bidirectional term <-> id mapping (the dictionary-encoding frontend)."""

    def __init__(self):
        self._fwd: dict[str, int] = {}
        self._bwd: list[str] = []

    def id(self, term: str) -> int:
        if term not in self._fwd:
            i = len(self._bwd)
            # id MAX_ID is reserved: the triple (MAX_ID, MAX_ID, MAX_ID)
            # would pack to INF_KEY, the store's padding sentinel
            if i >= MAX_ID:
                raise ValueError("term dictionary overflow (>= 2^21 - 1 terms)")
            self._fwd[term] = i
            self._bwd.append(term)
        return self._fwd[term]

    def term(self, i: int) -> str:
        return self._bwd[i]

    def lookup(self, term: str) -> int | None:
        """Read-only id lookup (None when absent). Query parsing must NOT
        mint ids: a constant unknown to the data is a parse-time error,
        not a fresh dictionary entry (which would silently match nothing
        and grow the dictionary under adversarial query streams)."""
        return self._fwd.get(term)

    def __len__(self) -> int:
        return len(self._bwd)

    def terms(self) -> list[str]:
        """Snapshot of the id -> term table (index i holds the term whose
        id is i). The mutable store persists this in its snapshot and logs
        increments to the WAL, so the dictionary survives restarts without
        a full rebuild."""
        return list(self._bwd)

    def replay_term(self, idx: int, term: str) -> None:
        """Idempotently apply a WAL-logged dictionary append: assign `term`
        id `idx`. Replaying the same record twice is a no-op; a CONFLICTING
        assignment (same id, different term — a corrupted or cross-wired
        log) is an error, as is a gap (ids are dense by construction)."""
        if idx < len(self._bwd):
            if self._bwd[idx] != term:
                raise ValueError(
                    f"dictionary replay conflict: id {idx} is "
                    f"{self._bwd[idx]!r}, log says {term!r}")
            return
        if idx != len(self._bwd):
            raise ValueError(
                f"dictionary replay gap: next id is {len(self._bwd)}, "
                f"log assigns {idx}")
        if idx >= MAX_ID:
            raise ValueError("term dictionary overflow (>= 2^21 - 1 terms)")
        self._fwd[term] = idx
        self._bwd.append(term)

    def encode_triples(self, triples: Iterable[tuple[str, str, str]]) -> np.ndarray:
        out = np.array([[self.id(s), self.id(p), self.id(o)]
                        for s, p, o in triples], np.int32)
        return out.reshape(-1, 3)

    def pattern(self, s: str, p: str, o: str) -> Pattern:
        """Strings starting with '?' stay variables, others are encoded."""
        conv = lambda t: t if t.startswith("?") else self.id(t)
        return Pattern(conv(s), conv(p), conv(o))
