"""Mutable triple store: delta overlays + WAL + crash-consistent
compaction (DESIGN.md §9).

Mirrors the HBase storage model the source paper sits on:

  * **memstore analog** — per-index sorted delta overlays held on the
    host, disjoint from the base by construction (RDF set semantics);
    every refresh merges overlay keys into per-shard sorted rows and a
    globally sorted flat view, so `probe()` / `dist_probe` / the batched
    serving cascades see ONE sorted index and need no code changes;
  * **WAL** — every ingest batch is framed, checksummed, and fsynced
    (`store/wal.py`) BEFORE it is applied; acknowledged == fsynced;
  * **flush / compaction** — when any shard's overlay exceeds
    ``overlay_limit``, the overlay is merged into the base, resharded
    with the exact `_shard_sorted` used by `build_store` (bit-identical
    layout semantics), snapshotted to disk, and the WAL rotated — each
    step ordered so that a crash at ANY point recovers to a store whose
    query results equal a fresh `build_store` over the acked triples;
  * **versioned invalidation** — every applied mutation calls
    ``bump_version()``: `store_version` advances, `plan_cache` (flat key
    views, relation_stats, cardinalities, compiled plans and cascades)
    is dropped wholesale, and `layout_key` changes so the serving
    engine's compile/signature caches miss instead of serving rows from
    a pre-ingest world.

Shape discipline (TPU requirement — static shapes): the merged rows are
``(num_shards, base_cap + ovl_cap)`` where ``ovl_cap`` is the CURRENT
max per-shard overlay depth rounded up on the planner's
``{2^k, 3*2^(k-1)}`` quantize grid — overlay growth re-pads on grid
steps only, and the flush threshold bounds ``ovl_cap`` from above, so
compile diversity stays bounded exactly like every other capacity in
the system.

Global-sortedness subtlety: the per-shard merged rows carry INF padding
at the END OF EVERY ROW (overlay headroom), so ``keys().reshape(-1)``
is NOT globally sorted the way the immutable store's is. The local
executor, the planner's host statistics, and the batched local cascade
all `searchsorted` over ``flat_keys`` — this class therefore OVERRIDES
``flat_keys`` with a separately maintained globally-sorted merged flat
view (all real keys ascending, single INF tail). The sharded paths are
untouched: each shard row is independently sorted and mask/searchsorted
logic already tolerates row-tail padding.
"""
from __future__ import annotations

import json
import os
import time

import jax.numpy as jnp
import numpy as np

from repro.core.rdf import INF_KEY, MAX_ID, Dictionary, pack3
from repro.core.triple_store import LRUCache, TripleStore, _shard_sorted
from repro.store.wal import (REC_DICT, REC_TRIPLES, WalWriter,
                             decode_dict_payload, decode_triples_payload,
                             encode_dict_payload, encode_triples_payload,
                             read_wal)

MANIFEST = "MANIFEST.json"


def _fsync_dir(path: str) -> None:
    """Make a rename in `path` durable (POSIX: fsync the directory)."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _write_manifest(root: str, manifest: dict) -> None:
    """Atomic MANIFEST update: tmp + fsync + os.replace + dir fsync. A
    crash leaves either the old or the new manifest, never a torn one —
    the manifest is the single commit point of a flush."""
    tmp = os.path.join(root, MANIFEST + ".tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, os.path.join(root, MANIFEST))
    _fsync_dir(root)


def _read_manifest(root: str) -> dict:
    with open(os.path.join(root, MANIFEST)) as f:
        return json.load(f)


def _terms_to_arrays(terms: list[str]) -> tuple[np.ndarray, np.ndarray]:
    """Dictionary terms -> (lengths, utf8 blob) — npz-storable without
    pickle (object arrays would need allow_pickle on load)."""
    raw = [t.encode("utf-8") for t in terms]
    lens = np.array([len(r) for r in raw], np.int64)
    blob = np.frombuffer(b"".join(raw), np.uint8) if raw else \
        np.zeros(0, np.uint8)
    return lens, blob


def _terms_from_arrays(lens: np.ndarray, blob: np.ndarray) -> list[str]:
    out, off, data = [], 0, blob.tobytes()
    for ln in lens:
        out.append(data[off:off + int(ln)].decode("utf-8"))
        off += int(ln)
    return out


def _quantized_ovl_cap(max_depth: int) -> int:
    """Overlay headroom on the planner's capacity grid (compile-time cap:
    row width only changes on grid steps)."""
    from repro.core.planner import quantize_cap
    return quantize_cap(max(int(max_depth), 1))


class MutableTripleStore(TripleStore):
    """`TripleStore` whose contents can grow at runtime, durably.

    Construct via :meth:`create` (fresh directory) or :meth:`open`
    (recovery: snapshot + WAL replay). All `TripleStore` consumers work
    unchanged — the dataclass fields always hold the CURRENT merged
    view, and `layout_key` carries `store_version` so caches keyed on
    the store can never cross a mutation.
    """

    def __init__(self, root: str, num_shards: int, overlay_limit: int,
                 dictionary: Dictionary, wal_writer: WalWriter,
                 base_spo: np.ndarray, base_ops: np.ndarray,
                 overlay_spo: np.ndarray, overlay_ops: np.ndarray,
                 init_version: int, metrics=None):
        self.root = root
        self.overlay_limit = int(overlay_limit)
        self.dictionary = dictionary
        self._wal = wal_writer
        self._num_shards = int(num_shards)
        # base: 1-D sorted unique int64; overlay: same, disjoint from base
        self._bk_spo = np.asarray(base_spo, np.int64)
        self._bk_ops = np.asarray(base_ops, np.int64)
        self._ov_spo = np.asarray(overlay_spo, np.int64)
        self._ov_ops = np.asarray(overlay_ops, np.int64)
        if metrics is None:
            from repro.obs.metrics import NULL_REGISTRY
            metrics = NULL_REGISTRY
        self._metrics = metrics
        self.flush_count = 0
        arrays = self._merged_arrays()
        TripleStore.__init__(
            self, **arrays,
            n_triples=len(self._bk_spo) + len(self._ov_spo),
            store_version=int(init_version), plan_cache=LRUCache())
        self._publish_metrics()

    # ------------------------------------------------------------------
    # construction / recovery
    # ------------------------------------------------------------------

    @classmethod
    def create(cls, root: str, num_shards: int = 1,
               overlay_limit: int = 4096, dictionary: Dictionary | None = None,
               fault_plan=None, metrics=None) -> "MutableTripleStore":
        """Initialize an empty durable store in `root` (created if needed;
        must not already hold a store)."""
        os.makedirs(root, exist_ok=True)
        if os.path.exists(os.path.join(root, MANIFEST)):
            raise ValueError(f"{root} already holds a store; use open()")
        manifest = {"format": 1, "num_shards": int(num_shards),
                    "snapshot": None, "wal": "wal-0.log", "start_seq": 0}
        _write_manifest(root, manifest)
        writer = WalWriter(os.path.join(root, manifest["wal"]),
                           start_seq=0, fault_plan=fault_plan)
        empty = np.zeros(0, np.int64)
        return cls(root, num_shards, overlay_limit,
                   dictionary or Dictionary(), writer,
                   empty, empty, empty, empty,
                   init_version=0, metrics=metrics)

    @classmethod
    def open(cls, root: str, overlay_limit: int = 4096,
             fault_plan=None, metrics=None) -> "MutableTripleStore":
        """Recover the store in `root`: load the snapshot, replay the
        WAL's durable prefix (torn tail truncated), rebuild the overlay.
        Read-only with respect to acked state — recovery never invents
        or drops an acknowledged triple, so results are bit-identical to
        `build_store` over the acked set. Recovery wall time is published
        as the `store_recovery_seconds` gauge."""
        t0 = time.perf_counter()
        manifest = _read_manifest(root)
        num_shards = int(manifest["num_shards"])
        start_seq = int(manifest["start_seq"])
        dictionary = Dictionary()
        base_spo = np.zeros(0, np.int64)
        base_ops = np.zeros(0, np.int64)
        if manifest["snapshot"]:
            with np.load(os.path.join(root, manifest["snapshot"])) as snap:
                base_spo = snap["keys_spo"].astype(np.int64)
                base_ops = snap["keys_ops"].astype(np.int64)
                terms = _terms_from_arrays(snap["term_lens"],
                                           snap["term_blob"])
            for i, t in enumerate(terms):
                dictionary.replay_term(i, t)
        # WalWriter repairs the torn tail, then we replay what survived
        writer = WalWriter(os.path.join(root, manifest["wal"]),
                           start_seq=start_seq, fault_plan=fault_plan)
        records, _, last_seq = read_wal(os.path.join(root, manifest["wal"]),
                                        start_seq=start_seq)
        replayed = []
        for _seq, rec_type, payload in records:
            if rec_type == REC_DICT:
                for idx, term in decode_dict_payload(payload):
                    dictionary.replay_term(idx, term)
            elif rec_type == REC_TRIPLES:
                replayed.append(decode_triples_payload(payload))
        ov_spo = np.zeros(0, np.int64)
        ov_ops = np.zeros(0, np.int64)
        if replayed:
            tri = np.concatenate(replayed)
            s, p, o = tri[:, 0], tri[:, 1], tri[:, 2]
            k_spo = np.unique(pack3(s, p, o))
            k_ops = np.unique(pack3(o, p, s))
            # overlay holds only what the base does not (set semantics)
            ov_spo = k_spo[~_sorted_isin(k_spo, base_spo)]
            ov_ops = k_ops[~_sorted_isin(k_ops, base_ops)]
        store = cls(root, num_shards, overlay_limit, dictionary, writer,
                    base_spo, base_ops, ov_spo, ov_ops,
                    init_version=last_seq + 1, metrics=metrics)
        store._metrics.gauge("store_recovery_seconds").set(
            time.perf_counter() - t0)
        return store

    # ------------------------------------------------------------------
    # mutation
    # ------------------------------------------------------------------

    def ingest(self, triples: np.ndarray) -> int:
        """Durably ingest an (N, 3) int id-triple batch: WAL append +
        fsync (the ack point), then apply to the overlay, flushing first
        if the overlay would exceed its per-shard limit. Returns the WAL
        sequence number the batch was acknowledged at. Re-ingesting an
        existing triple is a no-op for content (RDF set semantics)."""
        triples = np.asarray(triples, np.int64).reshape(-1, 3)
        self._validate(triples)
        self._flush_if_needed(triples)
        seq = self._wal.append(REC_TRIPLES,
                               encode_triples_payload(triples))
        self._wal.sync()          # <-- acknowledged
        self._apply(triples)
        self._metrics.counter("store_ingest_batches_total").inc()
        self._metrics.counter("store_ingest_triples_total").inc(
            len(triples))
        self._publish_metrics()
        return seq

    def ingest_terms(self, term_triples) -> int:
        """Durably ingest (s, p, o) STRING triples: newly minted
        dictionary entries and the encoded triples land in the same
        synced WAL write, so the dictionary grows without a rebuild and
        replay always defines a term before any triple references it."""
        before = len(self.dictionary)
        encoded = self.dictionary.encode_triples(term_triples)
        new_terms = [(i, self.dictionary.term(i))
                     for i in range(before, len(self.dictionary))]
        triples = np.asarray(encoded, np.int64).reshape(-1, 3)
        self._validate(triples)
        self._flush_if_needed(triples)
        if new_terms:
            self._wal.append(REC_DICT, encode_dict_payload(new_terms))
        seq = self._wal.append(REC_TRIPLES,
                               encode_triples_payload(triples))
        self._wal.sync()          # <-- acknowledged (terms + triples)
        self._apply(triples)
        self._metrics.counter("store_ingest_batches_total").inc()
        self._metrics.counter("store_ingest_triples_total").inc(
            len(triples))
        self._publish_metrics()
        return seq

    def flush(self) -> None:
        """Compact: merge the overlay into the base, reshard with the
        same `_shard_sorted` as `build_store` (bit-identical layout
        semantics — `repartition` hash-partitions and cannot reproduce
        the range layout), snapshot, rotate the WAL, commit via the
        MANIFEST. Crash-safe at every step: until the manifest replace
        lands, recovery uses the old snapshot + old WAL; after it, the
        new snapshot + empty WAL — both describe the same acked set
        (replay is idempotent)."""
        new_spo = _merge_disjoint(self._bk_spo, self._ov_spo)
        new_ops = _merge_disjoint(self._bk_ops, self._ov_ops)
        seq = self._wal.next_seq
        snap_name = f"snap-{seq}.npz"
        wal_name = f"wal-{seq}.log"
        term_lens, term_blob = _terms_to_arrays(self.dictionary.terms())
        tmp = os.path.join(self.root, snap_name + ".tmp")
        with open(tmp, "wb") as f:
            np.savez(f, keys_spo=new_spo, keys_ops=new_ops,
                     term_lens=term_lens, term_blob=term_blob)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, os.path.join(self.root, snap_name))
        _fsync_dir(self.root)
        old_wal_path = self._wal.path
        fault_plan = self._wal.fault_plan
        self._wal.close()
        new_writer = WalWriter(os.path.join(self.root, wal_name),
                               start_seq=seq, fault_plan=fault_plan)
        manifest = _read_manifest(self.root)
        old_snap = manifest["snapshot"]
        manifest.update(snapshot=snap_name, wal=wal_name, start_seq=seq)
        _write_manifest(self.root, manifest)   # <-- commit point
        # post-commit garbage is best-effort: stale files are harmless
        # (recovery only reads what the manifest names)
        for stale in (old_wal_path,
                      os.path.join(self.root, old_snap) if old_snap else None):
            if stale and os.path.exists(stale):
                try:
                    os.remove(stale)
                except OSError:
                    pass
        self._wal = new_writer
        self._bk_spo, self._bk_ops = new_spo, new_ops
        self._ov_spo = self._ov_spo[:0]
        self._ov_ops = self._ov_ops[:0]
        self.flush_count += 1
        self._metrics.counter("store_flush_total").inc()
        self._metrics.counter("store_compaction_total").inc()
        self._rebuild()
        self._publish_metrics()

    def close(self) -> None:
        self._wal.close()

    # ------------------------------------------------------------------
    # views / introspection
    # ------------------------------------------------------------------

    def flat_keys(self, index: int) -> jnp.ndarray:
        """Globally sorted merged flat view (base ∪ overlay ascending,
        single INF tail, same total size as the padded shard rows). The
        override exists because the merged shard ROWS carry overlay
        headroom padding at every row tail — `reshape(-1)` of those is
        not globally sorted, and `gather_range` / the planner's host
        statistics / `_probe_fanout` all binary-search a flat view."""
        key = ("flat_keys", index)
        if key not in self.plan_cache:
            bk = self._bk_spo if index == 0 else self._bk_ops
            ov = self._ov_spo if index == 0 else self._ov_ops
            merged = _merge_disjoint(bk, ov)
            flat = np.full(self.keys(index).size, INF_KEY, np.int64)
            flat[:len(merged)] = merged
            self.plan_cache[key] = jnp.asarray(flat)
        return self.plan_cache[key]

    @property
    def overlay_depth(self) -> int:
        """Total overlay triples not yet compacted into the base."""
        return int(len(self._ov_spo))

    @property
    def wal_bytes(self) -> int:
        return self._wal.synced_bytes

    @property
    def acked_seq(self) -> int:
        """Highest acknowledged WAL sequence number (-1 if none ever)."""
        return self._wal.next_seq - 1

    # ------------------------------------------------------------------
    # internals
    # ------------------------------------------------------------------

    def _validate(self, triples: np.ndarray) -> None:
        if len(triples) == 0:
            raise ValueError("empty ingest batch")
        if triples.min() < 0 or triples.max() > MAX_ID:
            raise ValueError(f"triple ids must be in [0, {MAX_ID}]")
        if np.any(np.all(triples == MAX_ID, axis=1)):
            raise ValueError("triple (MAX_ID, MAX_ID, MAX_ID) packs to "
                             "the INF_KEY sentinel and cannot be stored")

    def _delta_keys(self, triples: np.ndarray):
        """(new_spo, new_ops): the batch's keys not already present in
        base or overlay (sorted, unique)."""
        s, p, o = triples[:, 0], triples[:, 1], triples[:, 2]
        k_spo = np.unique(pack3(s, p, o))
        k_ops = np.unique(pack3(o, p, s))
        new_spo = k_spo[~_sorted_isin(k_spo, self._bk_spo)]
        new_spo = new_spo[~_sorted_isin(new_spo, self._ov_spo)]
        new_ops = k_ops[~_sorted_isin(k_ops, self._bk_ops)]
        new_ops = new_ops[~_sorted_isin(new_ops, self._ov_ops)]
        return new_spo, new_ops

    def _flush_if_needed(self, triples: np.ndarray) -> None:
        """Overflow check BEFORE the batch's WAL record is written: if
        folding the batch would push any shard's overlay past the limit,
        compact the EXISTING overlay into the base first. Ordering
        matters for durability — flush rotates the WAL away, so the
        triggering batch's record must land in the post-flush WAL (the
        snapshot taken by the flush does not contain the batch). If the
        overlay is already empty — one batch alone exceeds the limit —
        flushing can't help; the quantized ovl_cap simply escalates a
        grid step for this epoch instead."""
        if self.overlay_depth == 0:
            return
        new_spo, new_ops = self._delta_keys(triples)
        ov_spo = _merge_disjoint(self._ov_spo, new_spo)
        ov_ops = _merge_disjoint(self._ov_ops, new_ops)
        if max(self._max_shard_depth(ov_spo, self._bk_spo),
               self._max_shard_depth(ov_ops, self._bk_ops)) \
                > self.overlay_limit:
            self.flush()

    def _apply(self, triples: np.ndarray) -> None:
        """Fold an acked batch into the overlay (dedup against base and
        overlay: RDF set semantics)."""
        new_spo, new_ops = self._delta_keys(triples)
        if len(new_spo) == 0:
            return  # pure duplicates: acked, content unchanged, no bump
        self._ov_spo = _merge_disjoint(self._ov_spo, new_spo)
        self._ov_ops = _merge_disjoint(self._ov_ops, new_ops)
        self._rebuild()

    def _max_shard_depth(self, ov: np.ndarray, bk: np.ndarray) -> int:
        if len(ov) == 0:
            return 0
        _, splits, _ = _shard_sorted(bk, self._num_shards)
        assign = np.searchsorted(splits[1:self._num_shards], ov,
                                 side="left")
        return int(np.bincount(assign,
                               minlength=self._num_shards).max())

    def _merged_arrays(self) -> dict:
        """Merged per-shard rows + recomputed region boundaries for both
        indexes, as the dataclass field dict."""
        spo, sp_splits, sp_counts = self._merge_index(self._bk_spo,
                                                      self._ov_spo)
        ops, op_splits, op_counts = self._merge_index(self._bk_ops,
                                                      self._ov_ops)
        return dict(
            keys_spo=jnp.asarray(spo), keys_ops=jnp.asarray(ops),
            splits_spo=jnp.asarray(sp_splits),
            splits_ops=jnp.asarray(op_splits),
            counts_spo=jnp.asarray(sp_counts),
            counts_ops=jnp.asarray(op_counts))

    def _merge_index(self, bk: np.ndarray, ov: np.ndarray):
        """One index's merged view: base rows from `_shard_sorted` (the
        `build_store` layout), overlay keys routed to the shard whose
        base region covers them, each row re-sorted, rows padded to
        ``base_cap + ovl_cap``. Region boundaries are recomputed from
        the merged rows, and they only ever TIGHTEN within the base
        boundaries (an overlay key routed to shard k is ≤ the base
        boundary of k), so inter-shard ordering is preserved and probe
        routing stays exact."""
        S = self._num_shards
        base_pad, base_splits, base_counts = _shard_sorted(bk, S)
        base_cap = base_pad.shape[1]
        depth = self._max_shard_depth(ov, bk)
        ovl_cap = _quantized_ovl_cap(depth)
        width = base_cap + ovl_cap
        rows = np.full((S, width), INF_KEY, np.int64)
        counts = np.zeros(S, np.int64)
        splits = np.empty(S + 1, np.int64)
        splits[0] = np.int64(-1)
        assign = (np.searchsorted(base_splits[1:S], ov, side="left")
                  if len(ov) else np.zeros(0, np.int64))
        for k in range(S):
            b = bk[k * base_cap: min((k + 1) * base_cap, len(bk))]
            m = np.sort(np.concatenate([b, ov[assign == k]]))
            rows[k, :len(m)] = m
            counts[k] = len(m)
            splits[k + 1] = m[-1] if len(m) else splits[k]
        splits[S] = INF_KEY
        return rows, splits, counts

    def _rebuild(self) -> None:
        """Re-materialize the dataclass fields from base + overlay and
        advance the version (the mutation barrier: every store-keyed
        cache misses from here on)."""
        for name, val in self._merged_arrays().items():
            setattr(self, name, val)
        self.n_triples = len(self._bk_spo) + len(self._ov_spo)
        self.bump_version()

    def _publish_metrics(self) -> None:
        m = self._metrics
        m.gauge("store_overlay_depth").set(self.overlay_depth)
        m.gauge("store_wal_bytes").set(self.wal_bytes)
        m.gauge("store_n_triples").set(self.n_triples)
        m.gauge("store_version").set(self.store_version)


def _sorted_isin(needles: np.ndarray, haystack: np.ndarray) -> np.ndarray:
    """Membership of sorted `needles` in sorted unique `haystack` —
    searchsorted, no hashing."""
    if len(haystack) == 0:
        return np.zeros(len(needles), bool)
    pos = np.searchsorted(haystack, needles)
    pos = np.minimum(pos, len(haystack) - 1)
    return haystack[pos] == needles


def _merge_disjoint(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Union of two sorted, mutually disjoint unique arrays."""
    if len(a) == 0:
        return b.copy()
    if len(b) == 0:
        return a.copy()
    out = np.concatenate([a, b])
    out.sort()
    return out
