"""Checksummed append-only write-ahead log (DESIGN.md §9).

The WAL is the durability boundary of the mutable store: a triple (or
dictionary entry) is ACKNOWLEDGED exactly when the record holding it has
been written AND fsynced. A process killed at ANY byte boundary leaves a
durable prefix of complete records, possibly followed by one torn tail;
recovery replays the prefix and truncates the tail, so the recovered
store is bit-identical to a fresh build over the acknowledged data and
never contains an un-acked triple.

Record framing (little-endian)::

    MAGIC   u32   0x57414C31 ("WAL1") — resync sentinel / version tag
    seq     u64   monotonically increasing record sequence number
    type    u8    1 = triples batch, 2 = dictionary append
    length  u32   payload byte length
    payload bytes
    crc32   u32   zlib.crc32 over header + payload

The reader stops at the first record that is truncated, fails its CRC,
has the wrong magic, or regresses the sequence number — everything at or
past that point was never acknowledged. The writer, on reopen, truncates
the file back to the end of the valid prefix (torn-tail repair) before
appending, so one crash can never poison later appends.

Payloads:
  * ``REC_TRIPLES`` — N packed ``<u32 s, u32 p, u32 o>`` id triples.
  * ``REC_DICT``    — ``<u32 idx, u32 len>`` + utf-8 term bytes per entry;
    ``idx`` is the id the entry was minted with, so replay is idempotent
    (``Dictionary.replay_term``).

Fault injection: a :class:`~repro.serve.faults.DurabilityFaultPlan` hooks
``append``/``sync`` to simulate torn writes, lost un-synced bytes, and
process crashes at exact byte boundaries — the chaos harness for the
recovery path, mirroring what ``FaultPlan`` does for the a2a leg.
"""
from __future__ import annotations

import os
import struct
import zlib
from typing import Iterator, Optional

import numpy as np

MAGIC = 0x57414C31  # "WAL1"
_HEADER = struct.Struct("<IQBI")   # magic, seq, type, length
_CRC = struct.Struct("<I")
HEADER_SIZE = _HEADER.size         # 17
CRC_SIZE = _CRC.size               # 4

REC_TRIPLES = 1
REC_DICT = 2

_TRIPLE = struct.Struct("<III")
_DICT_ENT = struct.Struct("<II")


def encode_record(seq: int, rec_type: int, payload: bytes) -> bytes:
    head = _HEADER.pack(MAGIC, seq, rec_type, len(payload))
    crc = zlib.crc32(head + payload) & 0xFFFFFFFF
    return head + payload + _CRC.pack(crc)


def encode_triples_payload(triples: np.ndarray) -> bytes:
    """(N, 3) int array -> payload bytes."""
    t = np.ascontiguousarray(np.asarray(triples, np.uint32))
    return t.tobytes()


def decode_triples_payload(payload: bytes) -> np.ndarray:
    if len(payload) % _TRIPLE.size:
        raise ValueError("triples payload length not a multiple of 12")
    return np.frombuffer(payload, np.uint32).reshape(-1, 3).astype(np.int32)


def encode_dict_payload(entries: list[tuple[int, str]]) -> bytes:
    parts = []
    for idx, term in entries:
        raw = term.encode("utf-8")
        parts.append(_DICT_ENT.pack(idx, len(raw)))
        parts.append(raw)
    return b"".join(parts)


def decode_dict_payload(payload: bytes) -> list[tuple[int, str]]:
    out, off = [], 0
    while off < len(payload):
        if off + _DICT_ENT.size > len(payload):
            raise ValueError("dict payload truncated mid-entry header")
        idx, ln = _DICT_ENT.unpack_from(payload, off)
        off += _DICT_ENT.size
        if off + ln > len(payload):
            raise ValueError("dict payload truncated mid-term")
        out.append((idx, payload[off:off + ln].decode("utf-8")))
        off += ln
    return out


def scan_records(data: bytes, start_seq: int = 0
                 ) -> Iterator[tuple[int, int, int, bytes]]:
    """Yield ``(offset, seq, type, payload)`` for every valid record in
    the durable prefix of `data`; stop (silently) at the first torn,
    corrupt, or sequence-regressing record. ``offset`` is the byte
    offset where the record starts — the offset AFTER the last yielded
    record is the repair-truncation point."""
    off, expect = 0, start_seq
    n = len(data)
    while off + HEADER_SIZE + CRC_SIZE <= n:
        magic, seq, rec_type, length = _HEADER.unpack_from(data, off)
        if magic != MAGIC:
            return
        end = off + HEADER_SIZE + length + CRC_SIZE
        if end > n:
            return  # torn tail: payload/crc never fully hit the disk
        body = data[off:off + HEADER_SIZE + length]
        (crc,) = _CRC.unpack_from(data, off + HEADER_SIZE + length)
        if (zlib.crc32(body) & 0xFFFFFFFF) != crc:
            return
        if seq < expect:
            return  # sequence regression: stale bytes past a truncation
        yield off, seq, rec_type, bytes(data[off + HEADER_SIZE:
                                             off + HEADER_SIZE + length])
        expect = seq + 1
        off = end


def read_wal(path: str, start_seq: int = 0
             ) -> tuple[list[tuple[int, int, bytes]], int, int]:
    """Read the durable prefix of the WAL at `path`.

    Returns ``(records, valid_end, last_seq)`` where `records` is a list
    of ``(seq, type, payload)``, `valid_end` is the byte offset the file
    should be truncated to on repair, and `last_seq` is the highest valid
    sequence number (``start_seq - 1`` if the log is empty)."""
    if not os.path.exists(path):
        return [], 0, start_seq - 1
    with open(path, "rb") as f:
        data = f.read()
    records, valid_end, last_seq = [], 0, start_seq - 1
    for off, seq, rec_type, payload in scan_records(data, start_seq):
        records.append((seq, rec_type, payload))
        valid_end = off + HEADER_SIZE + len(payload) + CRC_SIZE
        last_seq = seq
    return records, valid_end, last_seq


class WalWriter:
    """Appender with torn-tail repair and optional fault injection.

    ``append`` frames + writes a record (buffered in the OS page cache);
    ``sync`` flushes + fsyncs — only then is the record acknowledged.
    A :class:`DurabilityFaultPlan` (serve/faults.py) may tear the bytes
    of a specific record, drop everything un-synced at a crash point, or
    raise ``SimulatedCrash`` — all BEFORE the ack, so chaos runs exercise
    exactly the window real crashes occupy.
    """

    def __init__(self, path: str, start_seq: int = 0, fault_plan=None):
        self.path = path
        self.fault_plan = fault_plan
        records, valid_end, last_seq = read_wal(path, start_seq)
        self._seq = last_seq + 1
        # torn-tail repair: drop bytes past the valid prefix before
        # appending, so a pre-crash partial record can't shadow new data
        if os.path.exists(path):
            size = os.path.getsize(path)
            if size != valid_end:
                with open(path, "r+b") as f:
                    f.truncate(valid_end)
        self._f = open(path, "ab")
        self._synced_size = valid_end
        self._unsynced = 0

    @property
    def next_seq(self) -> int:
        return self._seq

    @property
    def synced_bytes(self) -> int:
        return self._synced_size

    def append(self, rec_type: int, payload: bytes) -> int:
        """Frame and write one record; returns its seq. NOT yet durable —
        call ``sync()`` before acknowledging."""
        seq = self._seq
        rec = encode_record(seq, rec_type, payload)
        if self.fault_plan is not None:
            rec = self.fault_plan.on_append(seq, rec, self)
        self._f.write(rec)
        self._seq += 1
        self._unsynced += len(rec)
        return seq

    def sync(self) -> None:
        """Flush + fsync: everything appended so far becomes acknowledged."""
        if self.fault_plan is not None:
            self.fault_plan.on_sync(self)
        self._f.flush()
        os.fsync(self._f.fileno())
        self._synced_size += self._unsynced
        self._unsynced = 0

    def drop_unsynced(self) -> None:
        """Fault-injection hook: discard buffered-but-unsynced bytes, as a
        power loss would. Truncates the file to the last synced size."""
        self._f.flush()
        self._f.close()
        with open(self.path, "r+b") as f:
            f.truncate(self._synced_size)
        self._f = open(self.path, "ab")
        self._unsynced = 0

    def close(self) -> None:
        if not self._f.closed:
            self._f.flush()
            os.fsync(self._f.fileno())
            self._f.close()
