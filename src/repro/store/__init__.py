"""Durable mutable storage: WAL + delta overlays + crash-consistent
compaction (DESIGN.md §9) — the HBase memstore/WAL/HFile analog under
the query stack."""
from repro.store.mutable import MutableTripleStore
from repro.store.wal import (REC_DICT, REC_TRIPLES, WalWriter, read_wal,
                             scan_records)

__all__ = ["MutableTripleStore", "WalWriter", "read_wal", "scan_records",
           "REC_DICT", "REC_TRIPLES"]
