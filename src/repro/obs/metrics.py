"""Serving metrics registry (DESIGN.md §8).

Prometheus-shaped primitives — ``Counter``, ``Gauge``, fixed-bucket
``Histogram`` — keyed by (name, labels) in a ``MetricsRegistry``.
``REGISTRY`` is the process-global default the serving engine records
into unless handed its own (tests) or ``metrics=False`` (disabled:
``NULL_REGISTRY``, every operation a no-op).

Snapshots come in two shapes: ``to_dict()`` (nested JSON — histograms
carry estimated p50/p99 so per-template / per-tenant latency SLOs read
straight off the snapshot) and ``to_prom_text()`` (Prometheus text
exposition: cumulative ``_bucket{le=...}`` counts + ``_sum``/``_count``).
``add_hook(interval_s, fn)`` registers a periodic snapshot callback the
engine ticks from ``step()``.

Quantiles are ESTIMATES, interpolated inside the bucket that crosses the
target rank — the standard histogram_quantile trade: O(n_buckets) memory
for bounded error set by the bucket grid, exact at bucket boundaries.
"""
from __future__ import annotations

import bisect
import time
from typing import Callable

# latency grid (seconds): ~1-2.5-5 per decade, 100µs .. 60s
DEFAULT_LATENCY_BUCKETS = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    1e-1, 2.5e-1, 5e-1, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0, float("inf"))

# batch sizes / small counts: powers of two up to 256
DEFAULT_SIZE_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256, float("inf"))


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def dec(self, n: float = 1.0) -> None:
        self.value -= n


class Histogram:
    """Fixed-bucket histogram: ``bounds`` are ascending upper edges, the
    last must be +inf. ``observe`` is a bisect + two adds."""
    __slots__ = ("bounds", "counts", "sum", "count", "max")

    def __init__(self, bounds=DEFAULT_LATENCY_BUCKETS):
        bounds = tuple(float(b) for b in bounds)
        if not bounds or bounds[-1] != float("inf"):
            bounds = bounds + (float("inf"),)
        if list(bounds) != sorted(set(bounds)):
            raise ValueError(f"bucket bounds not strictly ascending: {bounds}")
        self.bounds = bounds
        self.counts = [0] * len(bounds)
        self.sum = 0.0
        self.count = 0
        self.max = 0.0

    def observe(self, v: float) -> None:
        self.counts[bisect.bisect_left(self.bounds, v)] += 1
        self.sum += v
        self.count += 1
        if v > self.max:
            self.max = v

    def quantile(self, q: float) -> float:
        """Estimated q-quantile by linear interpolation inside the
        crossing bucket; the +inf bucket reports the observed max."""
        if self.count == 0:
            return 0.0
        target = q * self.count
        cum, lo = 0, 0.0
        for b, c in zip(self.bounds, self.counts):
            if c and cum + c >= target:
                if b == float("inf"):
                    return self.max
                return lo + (b - lo) * (target - cum) / c
            cum += c
            if b != float("inf"):
                lo = b
        return self.max

    def cumulative(self) -> list[tuple[float, int]]:
        out, cum = [], 0
        for b, c in zip(self.bounds, self.counts):
            cum += c
            out.append((b, cum))
        return out


def _label_key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


def _label_str(lk: tuple) -> str:
    return ",".join(f'{k}="{v}"' for k, v in lk)


class MetricsRegistry:
    """Get-or-create instrument store. One instrument per (name, labels);
    a name is pinned to one kind (counter/gauge/histogram) at first use."""

    def __init__(self):
        self._instruments: dict[tuple, object] = {}
        self._kinds: dict[str, str] = {}
        self._hooks: list[list] = []     # [interval_s, next_due, fn]

    def _get(self, kind: str, name: str, labels: dict, make):
        have = self._kinds.setdefault(name, kind)
        if have != kind:
            raise ValueError(f"metric {name!r} already registered as {have}")
        key = (name, _label_key(labels))
        inst = self._instruments.get(key)
        if inst is None:
            inst = self._instruments[key] = make()
        return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels, Counter)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels, Gauge)

    def histogram(self, name: str, buckets=None, **labels) -> Histogram:
        bounds = DEFAULT_LATENCY_BUCKETS if buckets is None else buckets
        return self._get("histogram", name, labels,
                         lambda: Histogram(bounds))

    # --- snapshots -------------------------------------------------------

    def to_dict(self) -> dict:
        out: dict = {"counters": {}, "gauges": {}, "histograms": {}}
        for (name, lk), inst in sorted(self._instruments.items()):
            key = f"{name}{{{_label_str(lk)}}}" if lk else name
            if isinstance(inst, Counter):
                out["counters"][key] = inst.value
            elif isinstance(inst, Gauge):
                out["gauges"][key] = inst.value
            else:
                out["histograms"][key] = {
                    "count": inst.count, "sum": inst.sum, "max": inst.max,
                    "p50": inst.quantile(0.50), "p99": inst.quantile(0.99),
                    "buckets": {("+Inf" if b == float("inf") else repr(b)): c
                                for b, c in inst.cumulative()},
                }
        return out

    def to_prom_text(self) -> str:
        lines: list[str] = []
        by_name: dict[str, list] = {}
        for (name, lk), inst in sorted(self._instruments.items()):
            by_name.setdefault(name, []).append((lk, inst))
        for name, insts in by_name.items():
            lines.append(f"# TYPE {name} {self._kinds[name]}")
            for lk, inst in insts:
                ls = _label_str(lk)
                if isinstance(inst, (Counter, Gauge)):
                    lines.append(f"{name}{{{ls}}} {inst.value:g}" if ls
                                 else f"{name} {inst.value:g}")
                else:
                    for b, cum in inst.cumulative():
                        le = "+Inf" if b == float("inf") else f"{b:g}"
                        sep = "," if ls else ""
                        lines.append(
                            f'{name}_bucket{{{ls}{sep}le="{le}"}} {cum}')
                    lines.append(f"{name}_sum{{{ls}}} {inst.sum:g}" if ls
                                 else f"{name}_sum {inst.sum:g}")
                    lines.append(f"{name}_count{{{ls}}} {inst.count}" if ls
                                 else f"{name}_count {inst.count}")
        return "\n".join(lines) + ("\n" if lines else "")

    # --- periodic snapshot hook -----------------------------------------

    def add_hook(self, interval_s: float,
                 fn: Callable[["MetricsRegistry"], None]) -> None:
        """Register `fn(registry)` to fire at most every `interval_s`
        seconds, evaluated on `tick()` (the engine ticks once per step —
        no background thread, so a quiet engine fires no hooks).  The
        first tick arms the interval in the caller's clock domain (wall
        by default, virtual when `tick(now=...)` is driven by a replay)."""
        self._hooks.append([interval_s, None, fn])

    def tick(self, now: float | None = None) -> int:
        now = time.monotonic() if now is None else now
        fired = 0
        for hook in self._hooks:
            if hook[1] is None:
                hook[1] = now + hook[0]
            elif now >= hook[1]:
                hook[1] = now + hook[0]
                hook[2](self)
                fired += 1
        return fired

    def reset(self) -> None:
        self._instruments.clear()
        self._kinds.clear()


class _NullInstrument:
    """Shared no-op stand-in for every instrument kind."""
    __slots__ = ()
    value = 0.0
    count = 0
    sum = 0.0
    max = 0.0

    def inc(self, n: float = 1.0) -> None: pass
    def dec(self, n: float = 1.0) -> None: pass
    def set(self, v: float) -> None: pass
    def observe(self, v: float) -> None: pass
    def quantile(self, q: float) -> float: return 0.0


class NullRegistry(MetricsRegistry):
    """Disabled registry: hands out one shared no-op instrument and
    snapshots empty — ``ServeEngine(metrics=False)`` uses this."""
    _null = _NullInstrument()

    def __init__(self):
        super().__init__()

    def counter(self, name, **labels): return self._null
    def gauge(self, name, **labels): return self._null
    def histogram(self, name, buckets=None, **labels): return self._null
    def add_hook(self, interval_s, fn): pass
    def tick(self, now=None): return 0


#: process-global default registry (the engine's ``metrics=None`` target)
REGISTRY = MetricsRegistry()

#: shared disabled registry (``metrics=False``)
NULL_REGISTRY = NullRegistry()
