"""Query-lifecycle span tracing (DESIGN.md §8).

A ``Tracer`` records named time intervals ("spans") with structured
attributes and exports them as Chrome trace-event JSON — the format
Perfetto (https://ui.perfetto.dev) and ``chrome://tracing`` load
directly. Two kinds of span, matching the two shapes of serving work:

* **track spans** (``async_id=None``) — engine-side work that happens
  strictly nested on a logical thread: ``submit``, ``step``,
  ``dispatch``, ``compile``, per-cascade-step work. Exported as ``ph:
  "X"`` complete events on one trace thread per ``track`` name.
* **async spans** (``async_id=<query rid>``) — per-query lifecycle
  intervals that OUTLIVE any single engine call: the root ``query``
  span (submit -> delivery), its ``queued`` waits and per-attempt
  ``rung`` spans. Exported as ``ph: "b"/"e"`` async event pairs keyed
  on the rid, so Perfetto renders each query as its own nested lane
  without one trace thread per request.

The tracer is deliberately dumb and allocation-light: ``begin``/``end``
append plain ``Span`` records stamped with a monotonic clock
(``time.perf_counter``); nothing is formatted until ``export``. The
serving engine holds ``tracer=None`` by default and guards every hook
with one ``is not None`` test — the off path adds no work (overhead
policy: DESIGN.md §8).
"""
from __future__ import annotations

import contextlib
import json
import time
from typing import Any, Callable


class Span:
    """One recorded interval. ``t1 is None`` while the span is open.

    A plain ``__slots__`` record, not a dataclass: span construction sits
    on the serving engine's per-query path, where the <= 2% tracing
    budget (DESIGN.md §8) is measured in hundreds of nanoseconds.
    ``span_id`` defaults to the object's identity — unique for the
    tracer's lifetime since every span stays referenced by its list."""

    __slots__ = ("name", "t0", "t1", "track", "attrs", "span_id",
                 "parent_id", "async_id")

    def __init__(self, name: str, t0: float, t1: float | None, track: str,
                 attrs: dict, span_id: int | None = None,
                 parent_id: int | None = None, async_id: int | None = None):
        self.name = name
        self.t0 = t0
        self.t1 = t1
        self.track = track
        self.attrs = attrs
        self.span_id = id(self) if span_id is None else span_id
        self.parent_id = parent_id
        self.async_id = async_id

    @property
    def dur(self) -> float:
        return (self.t1 if self.t1 is not None else self.t0) - self.t0

    def __repr__(self) -> str:
        return (f"Span({self.name!r}, track={self.track!r}, "
                f"dur={self.dur:.6f}, attrs={self.attrs!r})")


class Tracer:
    """Span recorder with Chrome trace-event export.

    ``begin``/``end`` handle non-lexical spans (a query span opens in
    ``submit`` and closes in a later ``step``); the ``span`` context
    manager handles lexical ones and maintains a parent stack.
    ``jax_profiler=True`` additionally brackets ``jax_bracket`` regions
    with ``jax.profiler.TraceAnnotation`` so engine dispatches line up
    with XLA's own profiler timeline."""

    def __init__(self, clock: Callable[[], float] = time.perf_counter,
                 jax_profiler: bool = False):
        self._clock = clock
        self.jax_profiler = jax_profiler
        self.spans: list[Span] = []
        self._open: dict[int, Span] = {}
        self._stack: list[Span] = []

    # --- recording -------------------------------------------------------

    def now(self) -> float:
        return self._clock()

    def begin(self, name: str, track: str = "engine",
              parent: Span | None = None, async_id: int | None = None,
              **attrs: Any) -> Span:
        sp = Span(name, self._clock(), None, track, attrs, None,
                  parent.span_id if parent is not None else None, async_id)
        self._open[sp.span_id] = sp
        return sp

    def end(self, span: Span, **attrs: Any) -> Span:
        if span.span_id not in self._open:
            raise ValueError(f"span {span.name!r} already ended")
        del self._open[span.span_id]
        span.t1 = self._clock()
        if attrs:
            span.attrs.update(attrs)
        self.spans.append(span)
        return span

    def record(self, name: str, t0: float, t1: float, track: str = "engine",
               parent: Span | None = None, async_id: int | None = None,
               **attrs: Any) -> Span:
        """Append an already-measured interval (explicit stamps on this
        tracer's clock) — for callees that timed themselves."""
        sp = Span(name, t0, t1, track, attrs, None,
                  parent.span_id if parent is not None else None, async_id)
        self.spans.append(sp)
        return sp

    @contextlib.contextmanager
    def span(self, name: str, track: str = "engine",
             parent: Span | None = None, async_id: int | None = None,
             **attrs: Any):
        if parent is None and self._stack:
            parent = self._stack[-1]
        sp = self.begin(name, track, parent, async_id, **attrs)
        self._stack.append(sp)
        try:
            yield sp
        finally:
            self._stack.pop()
            self.end(sp)

    def jax_bracket(self, name: str):
        """Optional ``jax.profiler`` annotation around a dispatch; a
        no-op context manager unless ``jax_profiler=True``."""
        if not self.jax_profiler:
            return contextlib.nullcontext()
        import jax
        return jax.profiler.TraceAnnotation(name)

    # --- introspection ---------------------------------------------------

    @property
    def open_count(self) -> int:
        return len(self._open)

    def open_spans(self) -> list[Span]:
        return list(self._open.values())

    def find(self, name: str, track: str | None = None) -> list[Span]:
        return [s for s in self.spans
                if s.name == name and (track is None or s.track == track)]

    def coverage(self, t0: float, t1: float, track: str = "engine") -> float:
        """Fraction of the wall interval [t0, t1] covered by the union of
        TOP-LEVEL (parentless) completed spans on `track` — the
        attributed-time metric behind the >= 95% acceptance gate. Child
        spans are excluded so nesting can never double-count."""
        if t1 <= t0:
            return 0.0
        ivs = sorted((max(s.t0, t0), min(s.t1, t1)) for s in self.spans
                     if s.track == track and s.parent_id is None
                     and s.t1 is not None and s.t1 > t0 and s.t0 < t1)
        covered, cur_lo, cur_hi = 0.0, None, None
        for lo, hi in ivs:
            if cur_hi is None or lo > cur_hi:
                if cur_hi is not None:
                    covered += cur_hi - cur_lo
                cur_lo, cur_hi = lo, hi
            else:
                cur_hi = max(cur_hi, hi)
        if cur_hi is not None:
            covered += cur_hi - cur_lo
        return covered / (t1 - t0)

    # --- Chrome trace-event export --------------------------------------

    def to_events(self) -> list[dict]:
        """Chrome trace events: one trace thread per distinct track name
        (``M``/thread_name metadata + ``X`` complete events, ts/dur in
        µs) plus ``b``/``e`` async pairs for per-query spans."""
        tids: dict[str, int] = {}
        events: list[dict] = []
        for track in sorted({s.track for s in self.spans}):
            tids[track] = len(tids) + 1
            events.append({"ph": "M", "pid": 1, "tid": tids[track],
                           "name": "thread_name", "args": {"name": track}})
        for s in self.spans:
            if s.t1 is None:
                continue
            args = _jsonable(s.attrs)
            if s.async_id is not None:
                common = {"pid": 1, "cat": s.track, "name": s.name,
                          "id": s.async_id}
                events.append({"ph": "b", "ts": s.t0 * 1e6, "args": args,
                               **common})
                events.append({"ph": "e", "ts": s.t1 * 1e6, **common})
            else:
                events.append({"ph": "X", "pid": 1, "tid": tids[s.track],
                               "cat": s.track, "name": s.name,
                               "ts": s.t0 * 1e6, "dur": s.dur * 1e6,
                               "args": args})
        return events

    def export(self, path: str) -> str:
        """Write the trace as a Perfetto-loadable JSON object; returns
        `path`. Open at https://ui.perfetto.dev -> "Open trace file"."""
        with open(path, "w") as f:
            json.dump({"traceEvents": self.to_events(),
                       "displayTimeUnit": "ms"}, f)
            f.write("\n")
        return path


def _jsonable(attrs: dict) -> dict:
    out = {}
    for k, v in attrs.items():
        if isinstance(v, (str, int, float, bool)) or v is None:
            out[k] = v
        elif isinstance(v, (tuple, list)):
            out[k] = [x if isinstance(x, (str, int, float, bool))
                      else str(x) for x in v]
        else:
            out[k] = str(v)
    return out


def load_chrome(path: str) -> list:
    """Load an exported trace back and return its event list; raises
    ValueError if the file is not schema-valid Chrome trace-event JSON
    (used by tests and the bench's artifact self-check)."""
    with open(path) as f:
        doc = json.load(f)
    events = doc.get("traceEvents")
    validate_events(events)
    return events


def validate_events(events) -> None:
    """Schema check: every event has ph/pid/ts (or is metadata), X events
    carry non-negative dur, and async b/e pairs balance per (cat, id)."""
    if not isinstance(events, list):
        raise ValueError("traceEvents is not a list")
    depth: dict[tuple, int] = {}
    for ev in events:
        if not isinstance(ev, dict) or "ph" not in ev or "pid" not in ev:
            raise ValueError(f"malformed event: {ev!r}")
        ph = ev["ph"]
        if ph == "M":
            continue
        if "ts" not in ev:
            raise ValueError(f"event missing ts: {ev!r}")
        if ph == "X":
            if ev.get("dur", -1) < 0:
                raise ValueError(f"X event without dur: {ev!r}")
        elif ph in ("b", "e"):
            key = (ev.get("cat"), ev.get("id"))
            if key[1] is None:
                raise ValueError(f"async event without id: {ev!r}")
            depth[key] = depth.get(key, 0) + (1 if ph == "b" else -1)
            if depth[key] < 0:
                raise ValueError(f"async 'e' before 'b' for {key}")
    bad = {k: d for k, d in depth.items() if d != 0}
    if bad:
        raise ValueError(f"unbalanced async spans: {bad}")


def spans_from_stats(tracer: Tracer, stats: list, parent: Span | None = None,
                     track: str = "engine",
                     async_id: int | None = None) -> list[Span]:
    """Convert the per-step dicts of an instrumented ``execute_local``
    run (which now stamp ``t0``/``t1`` on the tracer clock) into
    per-cascade-step child spans. Pass ``async_id`` when the parent
    lives on a per-query async lane so the children render in it."""
    out = []
    for i, st in enumerate(stats):
        if "t0" not in st or "t1" not in st:
            continue
        attrs = {k: st[k] for k in ("kind", "n_in", "n_out", "overflow",
                                    "deliveries", "probe_len_max")
                 if k in st}
        out.append(tracer.record(f"cascade_step[{i}]", st["t0"], st["t1"],
                                 track=track, parent=parent,
                                 async_id=async_id, **attrs))
    return out
