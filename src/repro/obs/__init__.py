"""Observability for the serving stack (DESIGN.md §8): query-lifecycle
span tracing with Perfetto-loadable Chrome trace-event export, and a
Prometheus-shaped metrics registry with per-template / per-tenant SLO
histograms. Both are opt-in and allocation-light; the serving hot path
is untouched when they are off."""
from repro.obs.metrics import (DEFAULT_LATENCY_BUCKETS,
                               DEFAULT_SIZE_BUCKETS, Counter, Gauge,
                               Histogram, MetricsRegistry, NULL_REGISTRY,
                               NullRegistry, REGISTRY)
from repro.obs.trace import (Span, Tracer, load_chrome, spans_from_stats,
                             validate_events)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "NullRegistry",
    "REGISTRY", "NULL_REGISTRY", "DEFAULT_LATENCY_BUCKETS",
    "DEFAULT_SIZE_BUCKETS", "Span", "Tracer", "load_chrome",
    "spans_from_stats", "validate_events",
]
