from repro.models.api import (  # noqa: F401
    build_model, input_defs, make_decode_step, make_prefill_step,
    make_train_step,
)
