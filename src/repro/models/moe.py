"""Mixture-of-Experts layer with sort-based routed dispatch.

The dispatch is structurally the MAPSIN pattern (DESIGN.md §3): tokens are
*routed to the shard that owns the expert* — only the top-k routed
activations travel, never replicated expert weights and never an
all-tokens-to-all-experts shuffle. Under GSPMD (experts sharded over the
`model` axis, tokens over `data`) the scatter/gather pair lowers to
all-to-all-style collectives whose bytes are capacity-bounded.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common import ceil_div


def router_topk(x: jax.Array, w_router: jax.Array, top_k: int,
                num_experts: int):
    """Returns (weights (T, k) fp32, expert_ids (T, k) int32, aux_loss)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        w_router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, top_k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # load-balance auxiliary loss (Switch-style)
    me = probs.mean(axis=0)                                  # (E,)
    ce = jnp.zeros((num_experts,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    ce = ce / jnp.maximum(ce.sum(), 1.0)
    aux = num_experts * jnp.sum(me * ce)
    return weights, ids, aux


def capacity_of(num_tokens: int, num_experts: int, top_k: int,
                capacity_factor: float) -> int:
    return max(ceil_div(int(num_tokens * top_k * capacity_factor), num_experts), 4)


def moe_ffn(x: jax.Array, params: dict, *, top_k: int, num_experts: int,
            capacity_factor: float = 1.25, constrain=None):
    """x: (T, d) flat tokens. params: router (d,E), w_gate/w_up (E,d,f),
    w_down (E,f,d), optionally shared_* dense expert weights.

    `constrain(x, *logical_axes)` (optional) pins activation shardings so the
    per-expert buffers shard over (experts=EP, capacity=DP) — without it
    GSPMD may replicate the (E, C, d) buffer per chip at 671B scale.

    Returns (y (T, d), aux_loss, dropped_fraction).
    """
    t, d = x.shape
    constrain = constrain or (lambda a, *axes: a)
    weights, ids, aux = router_topk(x, params["router"], top_k, num_experts)
    cap = capacity_of(t, num_experts, top_k, capacity_factor)

    # ---- MAPSIN-style routed dispatch: sort (expert, token) pairs ----
    flat_e = ids.reshape(-1)                                  # (T*k,)
    flat_t = jnp.repeat(jnp.arange(t), top_k)
    flat_w = weights.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    # slot within expert = position - first position of that expert id
    first = jnp.searchsorted(se, jnp.arange(num_experts), side="left")
    slot = jnp.arange(t * top_k) - first[se]
    keep = slot < cap
    dropped = 1.0 - keep.mean()
    slot = jnp.where(keep, slot, cap)                         # overflow slot
    # gather tokens into per-expert buffers (E, cap+1, d); +1 = spill row
    buf = jnp.zeros((num_experts, cap + 1, d), x.dtype)
    buf = buf.at[se, slot].set(x[st] * keep[:, None].astype(x.dtype))
    buf = constrain(buf, "experts", "capacity", "embed")

    # ---- expert FFN, batched over experts (EP over `model` axis) ----
    g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, params["w_down"])
    y = constrain(y, "experts", "capacity", "embed")

    # ---- combine: route results back to token owners ----
    out = jnp.zeros((t, d), jnp.float32)
    contrib = y[se, slot].astype(jnp.float32) * (sw * keep)[:, None]
    out = out.at[st].add(contrib)

    if "shared_w_gate" in params:
        from repro.models.layers import swiglu
        out = out + swiglu(x, params["shared_w_gate"], params["shared_w_up"],
                           params["shared_w_down"]).astype(jnp.float32)
    return out.astype(x.dtype), aux, dropped
