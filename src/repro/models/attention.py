"""Attention implementations: blockwise-flash (XLA), exact-triangle variant,
naive reference, sliding-window local attention, decode steps, and MLA.

All functions take q: (b, sq, h, eq), k: (b, skv, g, eq), v: (b, skv, g, ev)
with h = g * rep (GQA). Softmax statistics are fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG = -1e30


def _split_heads(q: jax.Array, g: int) -> jax.Array:
    b, s, h, e = q.shape
    return q.reshape(b, s, g, h // g, e)


def _scores(qb: jax.Array, kb: jax.Array, scale: float) -> jax.Array:
    """qb: (b, Bq, g, r, e), kb: (b, Bk, g, e) -> (b, g, r, Bq, Bk) fp32."""
    s = jnp.einsum("bqgre,bkge->bgrqk", qb, kb,
                   preferred_element_type=jnp.float32)
    return s * scale


def _mask(q_pos: jax.Array, k_pos: jax.Array, kv_len: int,
          causal: bool, window: int) -> jax.Array:
    m = (k_pos[None, :] < kv_len)
    if causal:
        m = m & (k_pos[None, :] <= q_pos[:, None])
    if window:
        m = m & (q_pos[:, None] - k_pos[None, :] < window)
    return m  # (Bq, Bk)


def naive_attention(q, k, v, *, causal=True, window=0, scale=None):
    """Reference: materializes the full score matrix."""
    b, sq, h, eq = q.shape
    g = k.shape[2]
    scale = scale or eq ** -0.5
    qg = _split_heads(q, g)
    s = jnp.einsum("bqgre,bkge->bgrqk", qg, k,
                   preferred_element_type=jnp.float32) * scale
    q_pos = jnp.arange(sq) + (k.shape[1] - sq)  # right-aligned (decode-friendly)
    k_pos = jnp.arange(k.shape[1])
    m = _mask(q_pos, k_pos, k.shape[1], causal, window)
    s = jnp.where(m[None, None, None], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrqk,bkgf->bqgrf", p.astype(v.dtype), v)
    return o.reshape(b, sq, h, v.shape[-1])


def _flash_q_block(qb, k, v, q_start, kv_len, *, causal, window, block_kv,
                   scale, sink_stats=False, kv_producer=None, nk=None, ev=None):
    """Online-softmax over kv blocks for one q block.

    qb: (b, Bq, g, r, e). Returns (o, m, l) if sink_stats else o.
    kv_producer(j) -> (kj, vj) materializes one kv block on the fly (used by
    MLA prefill to up-project the latent per block instead of holding the
    full per-head K/V).
    """
    b, bq, g, r, e = qb.shape
    ev = v.shape[-1] if ev is None else ev
    nk = (k.shape[1] // block_kv) if nk is None else nk
    q_pos = q_start + jnp.arange(bq)

    @jax.checkpoint  # recompute block scores in backward (flash-style bwd)
    def body(carry, j):
        o, m, l = carry
        if kv_producer is not None:
            kj, vj = kv_producer(j)
        else:
            kj = jax.lax.dynamic_slice_in_dim(k, j * block_kv, block_kv, axis=1)
            vj = jax.lax.dynamic_slice_in_dim(v, j * block_kv, block_kv, axis=1)
        s = _scores(qb, kj, scale)  # (b, g, r, Bq, Bk)
        k_pos = j * block_kv + jnp.arange(block_kv)
        msk = _mask(q_pos, k_pos, kv_len, causal, window)[None, None, None]
        s = jnp.where(msk, s, NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None]) * msk
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bgrqk,bkgf->bgrqf", p.astype(vj.dtype), vj,
                        preferred_element_type=jnp.float32)
        o = o * alpha[..., None] + pv
        return (o, m_new, l), None

    o0 = jnp.zeros((b, g, r, bq, ev), jnp.float32)
    m0 = jnp.full((b, g, r, bq), NEG, jnp.float32)
    l0 = jnp.zeros((b, g, r, bq), jnp.float32)
    (o, m, l), _ = jax.lax.scan(body, (o0, m0, l0), jnp.arange(nk))
    if sink_stats:
        return o, m, l
    o = o / jnp.maximum(l, 1e-30)[..., None]
    return o


def blockwise_attention(q, k, v, *, causal=True, window=0, block_q=512,
                        block_kv=1024, scale=None):
    """Memory-efficient attention: double scan (q blocks x kv blocks) with
    online softmax — XLA's structural equivalent of flash attention. Baseline
    causal variant computes all (q, kv) block pairs (mask-only skipping)."""
    b, sq, h, eq = q.shape
    g = k.shape[2]
    ev = v.shape[-1]
    scale = scale or eq ** -0.5
    block_q = min(block_q, sq)
    block_kv = min(block_kv, k.shape[1])
    pad_q = (-sq) % block_q
    pad_kv = (-k.shape[1]) % block_kv
    kv_len = k.shape[1]
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    qg = _split_heads(q, g)
    nq = qg.shape[1] // block_q
    qblocks = qg.reshape(b, nq, block_q, g, h // g, eq).swapaxes(0, 1)

    @jax.checkpoint  # per-q-block remat: bwd never holds >1 block's scores
    def per_q(i, qb):
        o = _flash_q_block(qb, k, v, i * block_q, kv_len, causal=causal,
                           window=window, block_kv=block_kv, scale=scale)
        return o  # (b, g, r, Bq, ev)

    o = jax.lax.map(lambda t: per_q(t[0], t[1]), (jnp.arange(nq), qblocks))
    o = o.transpose(1, 0, 4, 2, 3, 5).reshape(b, nq * block_q, h, ev)
    return o[:, :sq].astype(v.dtype)


def triangle_attention(q, k, v, *, window=0, block_q=512, block_kv=1024,
                       scale=None):
    """Exact-FLOP causal attention: unrolled over q blocks, each scanning only
    kv blocks [0, i]. HLO grows O(nq) but compute matches the causal triangle
    (the §Perf 'xla_tri' hillclimb variant; see EXPERIMENTS.md)."""
    b, sq, h, eq = q.shape
    g = k.shape[2]
    ev = v.shape[-1]
    scale = scale or eq ** -0.5
    block_q = min(block_q, sq)
    block_kv = min(block_kv, k.shape[1])
    assert sq % block_q == 0 and k.shape[1] % block_kv == 0, "pad first"
    assert block_q % block_kv == 0 or block_kv % block_q == 0
    qg = _split_heads(q, g)
    nq = sq // block_q
    outs = []
    for i in range(nq):
        qb = qg[:, i * block_q:(i + 1) * block_q]
        hi = min(((i + 1) * block_q + block_kv - 1) // block_kv * block_kv,
                 k.shape[1])
        o = _flash_q_block(qb, k[:, :hi], v[:, :hi], i * block_q, hi,
                           causal=True, window=window, block_kv=block_kv,
                           scale=scale)
        outs.append(o)
    o = jnp.stack(outs, axis=1)  # (b, nq, g, r, Bq, ev)
    o = o.transpose(0, 1, 4, 2, 3, 5).reshape(b, sq, h, ev)
    return o.astype(v.dtype)


def local_attention(q, k, v, *, window, block_q=512, scale=None):
    """Sliding-window causal attention with O(sq * window) compute: for each
    q block, only the kv slice [q_start - window, q_start + Bq) is touched."""
    b, sq, h, eq = q.shape
    g = k.shape[2]
    ev = v.shape[-1]
    skv = k.shape[1]
    scale = scale or eq ** -0.5
    block_q = min(block_q, sq)
    pad_q = (-sq) % block_q
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    qg = _split_heads(q, g)
    nq = qg.shape[1] // block_q
    span = min(window + block_q, skv)
    qblocks = qg.reshape(b, nq, block_q, g, h // g, eq).swapaxes(0, 1)

    @jax.checkpoint  # see blockwise_attention
    def per_q(i, qb):
        q_start = i * block_q
        start = jnp.clip(q_start - window, 0, skv - span)
        kj = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
        vj = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
        s = _scores(qb, kj, scale)
        q_pos = q_start + jnp.arange(block_q)
        k_pos = start + jnp.arange(span)
        msk = ((k_pos[None] <= q_pos[:, None]) &
               (q_pos[:, None] - k_pos[None] < window) &
               (k_pos[None] < skv))[None, None, None]
        s = jnp.where(msk, s, NEG)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - m) * msk
        l = jnp.sum(p, axis=-1, keepdims=True)
        o = jnp.einsum("bgrqk,bkgf->bgrqf", (p / jnp.maximum(l, 1e-30)).astype(v.dtype), vj)
        return o

    o = jax.lax.map(lambda t: per_q(t[0], t[1]), (jnp.arange(nq), qblocks))
    o = o.transpose(1, 0, 4, 2, 3, 5).reshape(b, nq * block_q, h, ev)
    return o[:, :sq].astype(v.dtype)


def decode_attention(q, k_cache, v_cache, cur_len, *, window=0, scale=None):
    """One-step decode: q (b, 1, h, eq) against cache (b, S, g, e*).

    cur_len: int32 — number of valid cache positions (including this step's
    freshly inserted kv). For rotating window caches pass window=W and the
    cache length S == W; masking is slot-validity based.
    """
    b, _, h, eq = q.shape
    g = k_cache.shape[2]
    scale = scale or eq ** -0.5
    qg = q.reshape(b, g, h // g, eq)
    s = jnp.einsum("bgre,bsge->bgrs", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    slots = jnp.arange(k_cache.shape[1])
    if window:
        valid = slots < jnp.minimum(cur_len, window)
    else:
        valid = slots < cur_len
    s = jnp.where(valid[None, None, None], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrs,bsgf->bgrf", p.astype(v_cache.dtype), v_cache)
    return o.reshape(b, 1, h, v_cache.shape[-1])


def attention(q, k, v, *, impl="xla", causal=True, window=0, block_q=512,
              block_kv=1024, scale=None):
    """Dispatch on implementation. `pallas_interpret` validates the TPU
    Pallas kernel body on CPU; `xla` is the default lowering path."""
    if window and causal and impl in ("xla", "xla_tri"):
        return local_attention(q, k, v, window=window, block_q=block_q, scale=scale)
    if impl == "naive":
        return naive_attention(q, k, v, causal=causal, window=window, scale=scale)
    if impl == "xla_tri" and causal:
        return triangle_attention(q, k, v, window=window, block_q=block_q,
                                  block_kv=block_kv, scale=scale)
    if impl == "pallas_interpret":
        from repro.kernels import ops
        return ops.flash_attention(q, k, v, causal=causal, interpret=True)
    return blockwise_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_kv=block_kv, scale=scale)


def mla_prefill_attention(q, ckv, k_pe, kv_b_k, kv_b_v, *, scale,
                          block_q=512, block_kv=1024):
    """Blockwise causal MLA attention that up-projects the latent kv cache
    PER BLOCK — the full per-head K/V (b, s, h, e) is never materialized
    (at 32k x 128 heads that tensor is ~4 GiB/device-pass; the latent is 9x
    smaller). q: (b, s, h, dn+dr); ckv: (b, s, c); k_pe: (b, s, dr)."""
    b, sq, h, eq = q.shape
    dn = kv_b_k.shape[-1]
    dv = kv_b_v.shape[-1]
    skv = ckv.shape[1]
    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    pad_q = (-sq) % block_q
    pad_kv = (-skv) % block_kv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_kv:
        ckv = jnp.pad(ckv, ((0, 0), (0, pad_kv), (0, 0)))
        k_pe = jnp.pad(k_pe, ((0, 0), (0, pad_kv), (0, 0)))
    sq_p, skv_p = q.shape[1], ckv.shape[1]
    qg = q.reshape(b, sq_p, h, 1, eq)  # g == h, rep == 1
    nq = sq_p // block_q
    nk = skv_p // block_kv
    qblocks = qg.reshape(b, nq, block_q, h, 1, eq).swapaxes(0, 1)

    def producer(j):
        c_j = jax.lax.dynamic_slice_in_dim(ckv, j * block_kv, block_kv, axis=1)
        pe_j = jax.lax.dynamic_slice_in_dim(k_pe, j * block_kv, block_kv, axis=1)
        kn = jnp.einsum("bkc,chn->bkhn", c_j, kv_b_k)
        vv = jnp.einsum("bkc,chv->bkhv", c_j, kv_b_v)
        kk = jnp.concatenate(
            [kn, jnp.broadcast_to(pe_j[:, :, None, :], kn.shape[:3] + (pe_j.shape[-1],))],
            axis=-1)
        return kk, vv

    @jax.checkpoint
    def per_q(i, qb):
        return _flash_q_block(qb, None, None, i * block_q, skv, causal=True,
                              window=0, block_kv=block_kv, scale=scale,
                              kv_producer=producer, nk=nk, ev=dv)

    o = jax.lax.map(lambda t: per_q(t[0], t[1]), (jnp.arange(nq), qblocks))
    o = o.transpose(1, 0, 4, 2, 3, 5).reshape(b, nq * block_q, h, dv)
    return o[:, :sq].astype(ckv.dtype)


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, deepseek-v3)
# ---------------------------------------------------------------------------


def mla_absorbed_decode(q_nope, q_pe, ckv_cache, kpe_cache, kv_b_k, kv_b_v,
                        cur_len, *, scale):
    """Matrix-absorbed MLA decode: attention runs in the compressed KV space.

    q_nope: (b, h, dn), q_pe: (b, h, dr); ckv_cache: (b, S, c);
    kpe_cache: (b, S, dr); kv_b_k: (c, h, dn); kv_b_v: (c, h, dv).
    Never materializes per-head K/V for the 32k cache — scores are taken
    against the c-dim latent directly (the paper-era 'ship only what you
    need' economy applied to the KV cache).
    """
    qc = jnp.einsum("bhn,chn->bhc", q_nope, kv_b_k)         # absorb W_UK
    s = jnp.einsum("bhc,bsc->bhs", qc.astype(jnp.float32),
                   ckv_cache.astype(jnp.float32))
    s = s + jnp.einsum("bhr,bsr->bhs", q_pe.astype(jnp.float32),
                       kpe_cache.astype(jnp.float32))
    s = s * scale
    valid = jnp.arange(ckv_cache.shape[1]) < cur_len
    s = jnp.where(valid[None, None], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    oc = jnp.einsum("bhs,bsc->bhc", p.astype(ckv_cache.dtype), ckv_cache)
    o = jnp.einsum("bhc,chv->bhv", oc, kv_b_v)              # absorb W_UV
    return o  # (b, h, dv)
