"""Elementary layers shared by all architectures (pure functions on pytrees)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dtype)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies for rotary embeddings (half-dim)."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary position embedding.

    x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq).
    """
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., s, half)
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


def geglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
          w_down: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.gelu(g) * u, w_down)


def causal_conv1d(x: jax.Array, kernel: jax.Array,
                  state: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """Depthwise causal 1-D convolution.

    x: (b, s, c); kernel: (w, c). Returns (y, new_state) where state is the
    trailing (w-1) inputs for streaming decode.
    """
    w = kernel.shape[0]
    b, s, c = x.shape
    if state is None:
        state = jnp.zeros((b, w - 1, c), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)  # (b, s+w-1, c)
    y = jnp.zeros_like(x)
    for i in range(w):
        y = y + xp[:, i:i + s, :] * kernel[i]
    new_state = xp[:, -(w - 1):, :] if w > 1 else jnp.zeros((b, 0, c), x.dtype)
    return y, new_state


def softmax_xent_chunked(hidden: jax.Array, head_w: jax.Array,
                         labels: jax.Array, mask: jax.Array,
                         chunk: int = 512) -> jax.Array:
    """Cross-entropy without materializing full (b, s, vocab) fp32 logits.

    hidden: (b, s, d); head_w: (d, v) [vocab TP-sharded]; labels/mask: (b, s).
    Scans over sequence chunks; logits per chunk stay (b, chunk, v_local).
    """
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    n = s // chunk
    rem = s - n * chunk

    def chunk_loss(h, y, m):
        logits = jnp.einsum("bsd,dv->bsv", h, head_w).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        lab = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - lab) * m), jnp.sum(m)

    def body(carry, xs):
        tot, cnt = carry
        h, y, m = xs
        l, c = chunk_loss(h, y, m)
        return (tot + l, cnt + c), None

    hs = hidden[:, :n * chunk].reshape(b, n, chunk, d).swapaxes(0, 1)
    ys = labels[:, :n * chunk].reshape(b, n, chunk).swapaxes(0, 1)
    ms = mask[:, :n * chunk].reshape(b, n, chunk).swapaxes(0, 1)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)), (hs, ys, ms))
    if rem:
        l, c = chunk_loss(hidden[:, n * chunk:], labels[:, n * chunk:], mask[:, n * chunk:])
        tot, cnt = tot + l, cnt + c
    return tot / jnp.maximum(cnt, 1.0)
