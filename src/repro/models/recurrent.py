"""RecurrentGemma / Griffin: RG-LRU recurrent blocks + local attention (2:1).

The RG-LRU is a diagonal linear recurrence h_t = a_t * h_{t-1} + b_t — we
compute it with `jax.lax.associative_scan` (O(s log s) depth, O(s) work),
the TPU-native equivalent of the paper's sequential cell. Local attention
uses the O(s*window) sliding-window implementation from attention.py.

Layer pattern: (rec, rec, attn) macro-blocks scanned 12x, plus the two
trailing rec blocks (38 = 3*12 + 2) outside the scan.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.common import dtype_of
from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import embedding as embed_lib
from repro.models.layers import (apply_rope, causal_conv1d, geglu, rms_norm,
                                 softmax_xent_chunked)
from repro.models.params import pdef, stack_defs

C_LRU = 8.0  # Griffin's fixed recurrence-sharpness constant


def rg_lru_scan(u: jax.Array, log_a: jax.Array, h0: jax.Array | None):
    """u, log_a: (b, s, w) fp32. h_t = a_t h_{t-1} + u_t via associative scan."""
    a = jnp.exp(log_a)
    if h0 is not None:
        u = u.at[:, 0].add(a[:, 0] * h0)

    def combine(x, y):
        a1, u1 = x
        a2, u2 = y
        return a1 * a2, a2 * u1 + u2

    _, h = jax.lax.associative_scan(combine, (a, u), axis=1)
    return h  # (b, s, w)


class RecurrentGemmaLM:
    def __init__(self, cfg: ModelConfig, mesh=None, rules=None):
        self.cfg = cfg
        self.mesh = mesh
        self.rules = rules
        self.adt = dtype_of(cfg.activation_dtype)
        pat = cfg.block_pattern or ("rec",)
        self.layer_types = tuple(pat[i % len(pat)] for i in range(cfg.num_layers))
        # macro-block decomposition for scan-over-layers
        self.period = len(pat)
        self.n_macro = cfg.num_layers // self.period
        self.n_tail = cfg.num_layers - self.n_macro * self.period

    # ------------------------------------------------------------------
    def _rec_defs(self) -> dict[str, Any]:
        c = self.cfg
        d, w, pd = c.d_model, c.lru_width, c.param_dtype
        return {
            "norm": pdef((d,), ("embed",), pd, "ones"),
            "w_gate_br": pdef((d, w), ("fsdp", "lru"), pd),
            "w_x": pdef((d, w), ("fsdp", "lru"), pd),
            "conv": pdef((c.conv_width, w), (None, "lru"), pd, "normal", 0.1),
            "w_a": pdef((w, w), ("fsdp", "lru"), pd, "normal", 0.01),
            "b_a": pdef((w,), ("lru",), pd, "zeros"),
            "w_i": pdef((w, w), ("fsdp", "lru"), pd, "normal", 0.01),
            "b_i": pdef((w,), ("lru",), pd, "zeros"),
            "lam": pdef((w,), ("lru",), "float32", "ones"),
            "w_out": pdef((w, d), ("lru", "fsdp"), pd),
        }

    def _attn_defs(self) -> dict[str, Any]:
        c = self.cfg
        d, h, g, e, pd = c.d_model, c.num_heads, c.num_kv_heads, c.resolved_head_dim, c.param_dtype
        return {
            "norm": pdef((d,), ("embed",), pd, "ones"),
            "wq": pdef((d, h, e), ("fsdp", "heads", "head_dim"), pd),
            "wk": pdef((d, g, e), ("fsdp", "kv_heads", "head_dim"), pd),
            "wv": pdef((d, g, e), ("fsdp", "kv_heads", "head_dim"), pd),
            "wo": pdef((h, e, d), ("heads", "head_dim", "fsdp"), pd),
        }

    def _mlp_defs(self) -> dict[str, Any]:
        c = self.cfg
        d, f, pd = c.d_model, c.d_ff, c.param_dtype
        return {
            "norm": pdef((d,), ("embed",), pd, "ones"),
            "w_gate": pdef((d, f), ("fsdp", "mlp"), pd),
            "w_up": pdef((d, f), ("fsdp", "mlp"), pd),
            "w_down": pdef((f, d), ("mlp", "fsdp"), pd),
        }

    def _macro_defs(self) -> dict[str, Any]:
        out: dict[str, Any] = {}
        for i, t in enumerate(self.cfg.block_pattern):
            mix = self._rec_defs() if t == "rec" else self._attn_defs()
            out[f"b{i}"] = {"mix": mix, "mlp": self._mlp_defs()}
        return out

    def param_defs(self) -> dict[str, Any]:
        c = self.cfg
        d, v, pd = c.d_model, c.vocab_size, c.param_dtype
        defs: dict[str, Any] = {"embed": pdef((v, d), ("vocab", "fsdp"), pd)}
        if self.n_macro:
            defs["macros"] = stack_defs(self._macro_defs(), self.n_macro)
        for j in range(self.n_tail):
            t = self.cfg.block_pattern[j]
            mix = self._rec_defs() if t == "rec" else self._attn_defs()
            defs[f"tail{j}"] = {"mix": mix, "mlp": self._mlp_defs()}
        defs["final_norm"] = pdef((d,), ("embed",), pd, "ones")
        defs["lm_head"] = pdef((d, v), ("embed", "vocab"), pd)
        return defs

    # ------------------------------------------------------------------
    def _constrain(self, x, *axes):
        if self.rules is not None and self.mesh is not None:
            x = jax.lax.with_sharding_constraint(x, self.rules.sharding(*axes))
        return x

    def _rec_block(self, p, x, *, mode, cache=None):
        """cache: (h0 (b, w), conv_state (b, cw-1, w)) for decode."""
        c = self.cfg
        xs = rms_norm(x, p["norm"], c.norm_eps)
        gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", xs, p["w_gate_br"]))
        u = jnp.einsum("bsd,dw->bsw", xs, p["w_x"])
        conv_state = cache[1] if cache is not None else None
        u, new_conv = causal_conv1d(u, p["conv"], conv_state)
        uf = u.astype(jnp.float32)
        r = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", uf, p["w_a"].astype(jnp.float32))
                           + p["b_a"].astype(jnp.float32))
        i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", uf, p["w_i"].astype(jnp.float32))
                           + p["b_i"].astype(jnp.float32))
        log_a = -C_LRU * jax.nn.softplus(p["lam"]) * r          # (b, s, w), < 0
        beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
        b_in = beta * (i * uf)
        if mode == "decode":
            h0 = cache[0]
            h = jnp.exp(log_a[:, 0]) * h0 + b_in[:, 0]          # single step
            h = h[:, None]
            new_cache = (h[:, 0], new_conv)
        else:
            h = rg_lru_scan(b_in, log_a, None)
            new_cache = (h[:, -1], new_conv) if mode == "prefill" else None
        y = (h.astype(x.dtype) * gate)
        out = jnp.einsum("bsw,wd->bsd", y, p["w_out"])
        return x + out, new_cache

    def _attn_block(self, p, x, positions, *, mode, cache=None, cur_len=None):
        c = self.cfg
        xs = rms_norm(x, p["norm"], c.norm_eps)
        q = jnp.einsum("bsd,dhe->bshe", xs, p["wq"])
        k = jnp.einsum("bsd,dge->bsge", xs, p["wk"])
        v = jnp.einsum("bsd,dge->bsge", xs, p["wv"])
        q = apply_rope(q, positions, c.rope_theta)
        k = apply_rope(k, positions, c.rope_theta)
        if mode == "decode":
            kc, vc = cache
            W = kc.shape[1]
            idx = cur_len % W
            kc = jax.lax.dynamic_update_slice_in_dim(kc, k, idx, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(vc, v, idx, axis=1)
            o = attn_lib.decode_attention(q, kc, vc, cur_len + 1, window=W)
            new_cache = (kc, vc)
        else:
            o = attn_lib.local_attention(q, k, v, window=c.window_size,
                                         block_q=c.attn_block_q)
            if mode == "prefill":
                W = min(c.window_size, k.shape[1])
                new_cache = (k[:, -W:], v[:, -W:])
            else:
                new_cache = None
        out = jnp.einsum("bshe,hed->bsd", o, p["wo"])
        return x + out, new_cache

    def _block(self, p, x, positions, ltype, *, mode, cache=None, cur_len=None):
        x = self._constrain(x, "batch", "seq", "embed")
        if ltype == "rec":
            x, ncch = self._rec_block(p["mix"], x, mode=mode, cache=cache)
        else:
            x, ncch = self._attn_block(p["mix"], x, positions, mode=mode,
                                       cache=cache, cur_len=cur_len)
        xs = rms_norm(x, p["mlp"]["norm"], c_eps := self.cfg.norm_eps)
        x = x + geglu(xs, p["mlp"]["w_gate"], p["mlp"]["w_up"], p["mlp"]["w_down"])
        return x, ncch

    def _macro(self, p, x, positions, *, mode, caches=None, cur_len=None):
        new_caches = {}
        for i, t in enumerate(self.cfg.block_pattern):
            cch = caches[f"b{i}"] if caches is not None else None
            x, ncch = self._block(p[f"b{i}"], x, positions, t, mode=mode,
                                  cache=cch, cur_len=cur_len)
            new_caches[f"b{i}"] = ncch
        return x, new_caches

    # ------------------------------------------------------------------
    def cache_defs(self, batch: int, seq_len: int) -> dict[str, Any]:
        c = self.cfg
        dt = c.activation_dtype
        w = c.lru_width
        W = min(c.window_size, seq_len)
        g, e = c.num_kv_heads, c.resolved_head_dim

        def mix_cache(t):
            if t == "rec":
                return (pdef((batch, w), ("batch", "lru"), "float32", "zeros"),
                        pdef((batch, c.conv_width - 1, w), ("batch", None, "lru"), dt, "zeros"))
            return (pdef((batch, W, g, e), ("batch", None, "kv_heads", "head_dim"), dt, "zeros"),
                    pdef((batch, W, g, e), ("batch", None, "kv_heads", "head_dim"), dt, "zeros"))

        defs: dict[str, Any] = {}
        if self.n_macro:
            macro = {f"b{i}": mix_cache(t) for i, t in enumerate(c.block_pattern)}
            defs["macros"] = stack_defs(macro, self.n_macro)
        for j in range(self.n_tail):
            defs[f"tail{j}"] = mix_cache(c.block_pattern[j])
        defs["cur_len"] = pdef((), (), "int32", "zeros")
        return defs

    # ------------------------------------------------------------------
    def _run(self, params, x, positions, *, mode, cache=None, cur_len=None):
        c = self.cfg
        new_cache: dict[str, Any] = {}
        if self.n_macro:
            if mode == "train":
                def inner(p, xc):
                    # pin the saved value's sharding, then name it (see
                    # transformer._block for the ordering rationale)
                    xc = self._constrain(xc, "batch", "seq_ckpt", "embed")
                    xc = checkpoint_name(xc, "layer_in")
                    y, _ = self._macro(p, xc, positions, mode=mode)
                    return self._constrain(y, "batch", "seq_ckpt", "embed")

                if c.remat_policy == "names":
                    inner = jax.checkpoint(
                        inner,
                        policy=jax.checkpoint_policies.save_only_these_names("layer_in"))
                elif c.remat_policy != "none":
                    inner = jax.checkpoint(inner)

                def body(xc, p):
                    return inner(p, xc), None
                x, _ = jax.lax.scan(body, x, params["macros"])
            elif mode == "prefill":
                def body(xc, p):
                    y, ncch = self._macro(p, xc, positions, mode=mode)
                    return y, ncch
                x, ncc = jax.lax.scan(body, x, params["macros"])
                new_cache["macros"] = ncc
            else:
                def body(xc, xs):
                    p, cch = xs
                    y, ncch = self._macro(p, xc, positions, mode=mode,
                                          caches=cch, cur_len=cur_len)
                    return y, ncch
                x, ncc = jax.lax.scan(body, x, (params["macros"], cache["macros"]))
                new_cache["macros"] = ncc
        for j in range(self.n_tail):
            t = c.block_pattern[j]
            cch = cache[f"tail{j}"] if cache is not None else None
            x, ncch = self._block(params[f"tail{j}"], x, positions, t,
                                  mode=mode, cache=cch, cur_len=cur_len)
            if mode in ("prefill", "decode"):
                new_cache[f"tail{j}"] = ncch
        return x, new_cache

    def loss(self, params, batch):
        c = self.cfg
        tokens, labels = batch["tokens"], batch["labels"]
        x = embed_lib.embed(params["embed"], tokens, c.embedding_impl,
                            self.mesh, self.rules).astype(self.adt)
        positions = jnp.arange(x.shape[1])[None]
        x, _ = self._run(params, x, positions, mode="train")
        h = rms_norm(x, params["final_norm"], c.norm_eps)
        mask = (labels >= 0).astype(jnp.float32)
        ce = softmax_xent_chunked(h, params["lm_head"], labels, mask)
        return ce, {"ce": ce, "aux": jnp.float32(0)}

    def prefill(self, params, batch):
        c = self.cfg
        tokens = batch["tokens"]
        x = embed_lib.embed(params["embed"], tokens, c.embedding_impl,
                            self.mesh, self.rules).astype(self.adt)
        positions = jnp.arange(x.shape[1])[None]
        x, caches = self._run(params, x, positions, mode="prefill")
        h = rms_norm(x[:, -1:], params["final_norm"], c.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"])[:, 0]
        caches["cur_len"] = jnp.int32(tokens.shape[1])
        return logits, caches

    def decode_step(self, params, cache, tokens):
        c = self.cfg
        cur = cache["cur_len"]
        x = embed_lib.embed(params["embed"], tokens, c.embedding_impl,
                            self.mesh, self.rules).astype(self.adt)
        positions = jnp.full((1, 1), cur, jnp.int32)
        x, new_cache = self._run(params, x, positions, mode="decode",
                                 cache=cache, cur_len=cur)
        new_cache["cur_len"] = cur + 1
        h = rms_norm(x, params["final_norm"], c.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"])[:, 0]
        return logits, new_cache
