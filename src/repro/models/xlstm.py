"""xLSTM (arXiv:2405.04517): mLSTM (matrix memory) + sLSTM (scalar memory).

mLSTM is computed *chunkwise*: within a chunk the stabilized quadratic form,
across chunks a recurrent matrix state (C, n, m) — O(s * d^2) work, which is
what makes the `long_500k` cell runnable (sub-quadratic in sequence length).
sLSTM keeps the paper's sequential exponential-gated recurrence via
`lax.scan` over time with block-diagonal per-head recurrent weights.

Decode is O(1) per token for both cell types (the SSM selling point the
roofline table surfaces against the full-attention archs).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.common import dtype_of
from repro.configs.base import ModelConfig
from repro.models import embedding as embed_lib
from repro.models.layers import causal_conv1d, geglu, rms_norm, softmax_xent_chunked
from repro.models.params import pdef

CHUNK = 64


# ---------------------------------------------------------------------------
# mLSTM cell — chunkwise-parallel form with exponential-gating stabilization
# ---------------------------------------------------------------------------

def mlstm_chunkwise(q, k, v, log_i, log_f, state=None, chunk=CHUNK):
    """q, k, v: (b, s, h, e) fp32; log_i/log_f: (b, s, h) fp32.

    Returns (out (b, s, h, e), (C, n, m)) where the state stores
    true_C = C * exp(m) (stabilized), C: (b, h, e, e), n: (b, h, e), m: (b, h).
    """
    b, s, h, e = q.shape
    scale = e ** -0.5
    q = q * scale
    if s % chunk:
        pad = chunk - s % chunk
        q, k, v = (jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0))) for x in (q, k, v))
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)), constant_values=-30.0)
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))
    L = chunk
    nc = q.shape[1] // L

    def to_chunks(x):
        return x.reshape((b, nc, L) + x.shape[2:]).swapaxes(0, 1)

    qc, kc, vc, lic, lfc = map(to_chunks, (q, k, v, log_i, log_f))

    if state is None:
        C0 = jnp.zeros((b, h, e, e), jnp.float32)
        n0 = jnp.zeros((b, h, e), jnp.float32)
        m0 = jnp.full((b, h), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state

    def body(carry, xs):
        C, n, m = carry
        qj, kj, vj, li, lf = xs  # (b, L, h, e) / (b, L, h)
        lc = jnp.cumsum(lf, axis=1)                      # inclusive decay to t
        F = lc[:, -1]                                    # (b, h) total decay
        # intra-chunk log weights D[t, s] = lc_t - lc_s + li_s  (s <= t)
        D = lc[:, :, None, :] - lc[:, None, :, :] + li[:, None, :, :]
        tri = jnp.tril(jnp.ones((L, L), bool))
        D = jnp.where(tri[None, :, :, None], D, -1e30)   # (b, t, s, h)
        b_inter = lc + m[:, None, :]                     # (b, t, h)
        m_t = jnp.maximum(jnp.max(D, axis=2), b_inter)   # (b, t, h)
        w_intra = jnp.exp(D - m_t[:, :, None, :])        # (b, t, s, h)
        w_inter = jnp.exp(b_inter - m_t)                 # (b, t, h)
        scores = jnp.einsum("bthe,bshe->btsh", qj, kj) * w_intra
        num = jnp.einsum("btsh,bshe->bthe", scores, vj)
        num = num + jnp.einsum("bthe,bhef->bthf", qj, C) * w_inter[..., None]
        den = jnp.sum(scores, axis=2)                    # (b, t, h)
        den = den + jnp.einsum("bthe,bhe->bth", qj, n) * w_inter
        out = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # ---- state update to end of chunk ----
        key_decay = F[:, None, :] - lc + li              # (b, s, h)
        m_new = jnp.maximum(F + m, jnp.max(key_decay, axis=1))
        kw = jnp.exp(key_decay - m_new[:, None, :])      # (b, s, h)
        carry_w = jnp.exp(F + m - m_new)                 # (b, h)
        C_new = C * carry_w[..., None, None] + jnp.einsum(
            "bshe,bshf,bsh->bhef", kj, vj, kw)
        n_new = n * carry_w[..., None] + jnp.einsum("bshe,bsh->bhe", kj, kw)
        return (C_new, n_new, m_new), out

    (C, n, m), outs = jax.lax.scan(body, (C0, n0, m0), (qc, kc, vc, lic, lfc))
    out = outs.swapaxes(0, 1).reshape(b, nc * L, h, e)[:, :s]
    return out, (C, n, m)


def mlstm_decode(q, k, v, log_i, log_f, state):
    """Single-step recurrent mLSTM. q,k,v: (b, h, e); log_i/f: (b, h)."""
    C, n, m = state
    e = q.shape[-1]
    q = q * e ** -0.5
    m_new = jnp.maximum(log_f + m, log_i)
    i_w = jnp.exp(log_i - m_new)
    f_w = jnp.exp(log_f + m - m_new)
    C = C * f_w[..., None, None] + jnp.einsum("bhe,bhf,bh->bhef", k, v, i_w)
    n = n * f_w[..., None] + k * i_w[..., None]
    num = jnp.einsum("bhe,bhef->bhf", q, C)
    den = jnp.einsum("bhe,bhe->bh", q, n)
    out = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    return out, (C, n, m_new)


# ---------------------------------------------------------------------------
# sLSTM cell — sequential exponential-gated scalar memory
# ---------------------------------------------------------------------------

def slstm_step(x_t, h_prev, c_prev, n_prev, m_prev, p):
    """x_t: (b, h, e) gate pre-activations from input side live in p already
    combined; here x_t are the four stacked pre-acts (b, 4, h, e)."""
    rec = jnp.einsum("bhe,ghef->bghf", h_prev, p["R"])   # (b, 4, h, e)
    z_t = x_t + rec
    i_t, f_t, z_in, o_in = z_t[:, 0], z_t[:, 1], z_t[:, 2], z_t[:, 3]
    m_new = jnp.maximum(f_t + m_prev, i_t)
    i = jnp.exp(i_t - m_new)
    f = jnp.exp(f_t + m_prev - m_new)
    c = f * c_prev + i * jnp.tanh(z_in)
    n = f * n_prev + i
    h = jax.nn.sigmoid(o_in) * c / jnp.maximum(n, 1e-6)
    return h, c, n, m_new


def slstm_seq(x_gates, p, state=None):
    """x_gates: (b, s, 4, h, e) fp32. Sequential scan over time."""
    b, s, _, h, e = x_gates.shape
    if state is None:
        z = jnp.zeros((b, h, e), jnp.float32)
        state = (z, z, z, jnp.full((b, h, e), -1e30, jnp.float32))

    def body(carry, x_t):
        h_p, c_p, n_p, m_p = carry
        h_n, c_n, n_n, m_n = slstm_step(x_t, h_p, c_p, n_p, m_p, p)
        return (h_n, c_n, n_n, m_n), h_n

    state, hs = jax.lax.scan(body, state, x_gates.swapaxes(0, 1))
    return hs.swapaxes(0, 1), state  # (b, s, h, e), state


# ---------------------------------------------------------------------------
# Blocks + model
# ---------------------------------------------------------------------------


class XLSTMLM:
    def __init__(self, cfg: ModelConfig, mesh=None, rules=None):
        self.cfg = cfg
        self.mesh = mesh
        self.rules = rules
        self.adt = dtype_of(cfg.activation_dtype)
        self.inner = int(cfg.d_model * cfg.mlstm_proj_factor)
        self.heads = cfg.num_heads
        self.he_m = self.inner // self.heads   # mLSTM head dim
        self.he_s = cfg.d_model // self.heads  # sLSTM head dim

    def _mlstm_defs(self) -> dict[str, Any]:
        c, d, inner, h, e = self.cfg, self.cfg.d_model, self.inner, self.heads, self.he_m
        pd = c.param_dtype
        return {
            "norm": pdef((d,), ("embed",), pd, "ones"),
            "w_up": pdef((d, 2 * inner), ("fsdp", "inner"), pd),
            "conv": pdef((c.conv_width, inner), (None, "inner"), pd, "normal", 0.1),
            "wq": pdef((inner, h, e), ("inner", "heads", None), pd),
            "wk": pdef((inner, h, e), ("inner", "heads", None), pd),
            "wv": pdef((inner, h, e), ("inner", "heads", None), pd),
            "w_if": pdef((inner, 2 * h), ("inner", None), "float32", "zeros"),
            "b_i": pdef((h,), ("heads",), "float32", "zeros"),
            "b_f": pdef((h,), ("heads",), "float32", "ones"),
            "gn": pdef((inner,), ("inner",), pd, "ones"),
            "w_down": pdef((inner, d), ("inner", "fsdp"), pd),
        }

    def _slstm_defs(self) -> dict[str, Any]:
        c, d, h, e = self.cfg, self.cfg.d_model, self.heads, self.he_s
        pd = c.param_dtype
        f = int(d * c.slstm_proj_factor)
        return {
            "norm": pdef((d,), ("embed",), pd, "ones"),
            "W": pdef((d, 4, h, e), ("fsdp", None, "heads", None), "float32", "normal", 0.02),
            "R": pdef((4, h, e, e), (None, "heads", None, None), "float32", "normal", 0.02),
            "b": pdef((4, h, e), (None, "heads", None), "float32", "zeros"),
            "gn": pdef((d,), ("embed",), pd, "ones"),
            "ffn_norm": pdef((d,), ("embed",), pd, "ones"),
            "w_gate": pdef((d, f), ("fsdp", "mlp"), pd),
            "w_up": pdef((d, f), ("fsdp", "mlp"), pd),
            "w_down": pdef((f, d), ("mlp", "fsdp"), pd),
        }

    def param_defs(self) -> dict[str, Any]:
        c = self.cfg
        d, v, pd = c.d_model, c.vocab_size, c.param_dtype
        defs: dict[str, Any] = {"embed": pdef((v, d), ("vocab", "fsdp"), pd)}
        for i in range(c.num_layers):
            if i in c.slstm_at:
                defs[f"layer{i}"] = self._slstm_defs()
            else:
                defs[f"layer{i}"] = self._mlstm_defs()
        defs["final_norm"] = pdef((d,), ("embed",), pd, "ones")
        if not c.tie_embeddings:
            defs["lm_head"] = pdef((d, v), ("embed", "vocab"), pd)
        return defs

    # ------------------------------------------------------------------
    def _mlstm_block(self, p, x, *, mode, cache=None):
        c = self.cfg
        b, s, d = x.shape
        h, e = self.heads, self.he_m
        xs = rms_norm(x, p["norm"], c.norm_eps)
        up = jnp.einsum("bsd,di->bsi", xs, p["w_up"])
        xm, z = jnp.split(up, 2, axis=-1)
        conv_state = cache[3] if cache is not None else None
        xc, new_conv = causal_conv1d(xm, p["conv"], conv_state)
        xc = jax.nn.silu(xc)
        q = jnp.einsum("bsi,ihe->bshe", xc, p["wq"]).astype(jnp.float32)
        k = jnp.einsum("bsi,ihe->bshe", xc, p["wk"]).astype(jnp.float32)
        v = jnp.einsum("bsi,ihe->bshe", xm, p["wv"]).astype(jnp.float32)
        gif = jnp.einsum("bsi,ig->bsg", xc.astype(jnp.float32), p["w_if"])
        log_i = gif[..., :h] + p["b_i"]
        log_f = jax.nn.log_sigmoid(gif[..., h:] + p["b_f"])
        if mode == "decode":
            state = cache[:3]
            out, new_state = mlstm_decode(q[:, 0], k[:, 0], v[:, 0],
                                          log_i[:, 0], log_f[:, 0], state)
            out = out[:, None]
            new_cache = new_state + (new_conv,)
        else:
            state = cache[:3] if cache is not None else None
            out, new_state = mlstm_chunkwise(q, k, v, log_i, log_f, state)
            new_cache = new_state + (new_conv,) if mode == "prefill" else None
        out = out.reshape(b, s, self.inner).astype(x.dtype)
        out = rms_norm(out, p["gn"], c.norm_eps)  # group-norm stand-in
        out = out * jax.nn.silu(z)
        return x + jnp.einsum("bsi,id->bsd", out, p["w_down"]), new_cache

    def _slstm_block(self, p, x, *, mode, cache=None):
        c = self.cfg
        xs = rms_norm(x, p["norm"], c.norm_eps).astype(jnp.float32)
        gates = jnp.einsum("bsd,dghe->bsghe", xs, p["W"]) + p["b"]
        if mode == "decode":
            h_p, c_p, n_p, m_p = cache
            h_n, c_n, n_n, m_n = slstm_step(gates[:, 0], h_p, c_p, n_p, m_p, p)
            hs = h_n[:, None]
            new_cache = (h_n, c_n, n_n, m_n)
        else:
            hs, state = slstm_seq(gates, p, cache)
            new_cache = state if mode == "prefill" else None
        b, s = x.shape[:2]
        out = hs.reshape(b, s, c.d_model).astype(x.dtype)
        out = rms_norm(out, p["gn"], c.norm_eps)
        x = x + out
        xf = rms_norm(x, p["ffn_norm"], c.norm_eps)
        return x + geglu(xf, p["w_gate"], p["w_up"], p["w_down"]), new_cache

    def cache_defs(self, batch: int, seq_len: int) -> dict[str, Any]:
        c = self.cfg
        h, em, es = self.heads, self.he_m, self.he_s
        defs: dict[str, Any] = {}
        for i in range(c.num_layers):
            if i in c.slstm_at:
                z = pdef((batch, h, es), ("batch", "heads", None), "float32", "zeros")
                defs[f"layer{i}"] = (z, z, z, pdef((batch, h, es), ("batch", "heads", None), "float32", "zeros"))
            else:
                defs[f"layer{i}"] = (
                    pdef((batch, h, em, em), ("batch", "heads", None, None), "float32", "zeros"),
                    pdef((batch, h, em), ("batch", "heads", None), "float32", "zeros"),
                    pdef((batch, h), ("batch", "heads"), "float32", "zeros"),
                    pdef((batch, c.conv_width - 1, self.inner), ("batch", None, "inner"), c.activation_dtype, "zeros"),
                )
        defs["cur_len"] = pdef((), (), "int32", "zeros")
        return defs

    # ------------------------------------------------------------------
    def _run(self, params, x, *, mode, cache=None):
        c = self.cfg
        new_cache: dict[str, Any] = {}
        for i in range(c.num_layers):
            p = params[f"layer{i}"]
            cch = cache[f"layer{i}"] if cache is not None else None
            if i in c.slstm_at:
                x, ncch = self._slstm_block(p, x, mode=mode, cache=cch)
            else:
                x, ncch = self._mlstm_block(p, x, mode=mode, cache=cch)
            if mode in ("prefill", "decode"):
                new_cache[f"layer{i}"] = ncch
        return x, new_cache

    def _head(self, params):
        return params["embed"].T if self.cfg.tie_embeddings else params["lm_head"]

    def loss(self, params, batch):
        c = self.cfg
        tokens, labels = batch["tokens"], batch["labels"]
        x = embed_lib.embed(params["embed"], tokens, c.embedding_impl,
                            self.mesh, self.rules).astype(self.adt)
        x, _ = self._run(params, x, mode="train")
        h = rms_norm(x, params["final_norm"], c.norm_eps)
        mask = (labels >= 0).astype(jnp.float32)
        ce = softmax_xent_chunked(h, self._head(params), labels, mask)
        return ce, {"ce": ce, "aux": jnp.float32(0)}

    def prefill(self, params, batch):
        c = self.cfg
        tokens = batch["tokens"]
        x = embed_lib.embed(params["embed"], tokens, c.embedding_impl,
                            self.mesh, self.rules).astype(self.adt)
        x, caches = self._run(params, x, mode="prefill")
        h = rms_norm(x[:, -1:], params["final_norm"], c.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", h, self._head(params))[:, 0]
        caches["cur_len"] = jnp.int32(tokens.shape[1])
        return logits, caches

    def decode_step(self, params, cache, tokens):
        c = self.cfg
        cur = cache["cur_len"]
        x = embed_lib.embed(params["embed"], tokens, c.embedding_impl,
                            self.mesh, self.rules).astype(self.adt)
        x, new_cache = self._run(params, x, mode="decode", cache=cache)
        new_cache["cur_len"] = cur + 1
        h = rms_norm(x, params["final_norm"], c.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", h, self._head(params))[:, 0]
        return logits, new_cache
