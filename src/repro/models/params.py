"""Parameter definition trees: one source of truth for shapes, init, sharding.

Models declare ``ParamDef`` trees; from the same tree we materialize
 * concrete params (smoke tests / real training),
 * ``jax.ShapeDtypeStruct`` stand-ins (the multi-pod dry-run never allocates),
 * ``PartitionSpec`` trees via the logical-axis ``Rules``.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.common import dtype_of, fold_path, tree_map_with_path
from repro.sharding.rules import Rules


@dataclasses.dataclass(frozen=True)
class ParamDef:
    """A dataclass (not NamedTuple) so pytree utils treat it as a leaf."""
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]   # logical axis per dim (len == ndim)
    dtype: str = "bfloat16"
    init: str = "normal"           # normal | zeros | ones
    scale: float = 0.02


def pdef(shape, axes, dtype="bfloat16", init="normal", scale=0.02) -> ParamDef:
    shape = tuple(int(s) for s in shape)
    axes = tuple(axes)
    assert len(shape) == len(axes), (shape, axes)
    return ParamDef(shape, axes, dtype, init, scale)


def is_def(x: Any) -> bool:
    return isinstance(x, ParamDef)


def stack_defs(defs: Any, n: int, axis_name: str = "layers") -> Any:
    """Prepend a stacked (scan) dim of size n to every def in the tree."""
    def f(_, d: ParamDef):
        return ParamDef((n,) + d.shape, (axis_name,) + d.axes, d.dtype, d.init, d.scale)
    return tree_map_with_path(f, defs)


def init_tree(defs: Any, key: jax.Array) -> Any:
    def make(path, d: ParamDef):
        dt = dtype_of(d.dtype)
        if d.init == "zeros":
            return jnp.zeros(d.shape, dt)
        if d.init == "ones":
            return jnp.ones(d.shape, dt)
        k = fold_path(key, path)
        return (jax.random.normal(k, d.shape, jnp.float32) * d.scale).astype(dt)
    return tree_map_with_path(make, defs)


def abstract_tree(defs: Any, rules: Rules | None = None) -> Any:
    """ShapeDtypeStructs (with shardings when rules given) — zero allocation."""
    def make(_, d: ParamDef):
        sharding = rules.sharding(*d.axes) if rules is not None else None
        return jax.ShapeDtypeStruct(d.shape, dtype_of(d.dtype), sharding=sharding)
    return tree_map_with_path(make, defs)


def pspec_tree(defs: Any, rules: Rules) -> Any:
    return tree_map_with_path(lambda _, d: rules.pspec(*d.axes), defs)


def sharding_tree(defs: Any, rules: Rules) -> Any:
    return tree_map_with_path(lambda _, d: rules.sharding(*d.axes), defs)


def bytes_of(defs: Any) -> int:
    import numpy as np
    total = 0
    for _, d in _iter_defs(defs):
        total += int(np.prod(d.shape)) * dtype_of(d.dtype).dtype.itemsize
    return total


def sharded_bytes_per_device(defs: Any, rules: Rules) -> int:
    """Exact per-device resident bytes for a def tree under its shardings
    (ceil-division per sharded dim, matching GSPMD padding)."""
    import numpy as np
    mesh_shape = dict(rules.mesh.shape)
    total = 0
    for _, d in _iter_defs(defs):
        spec = rules.pspec(*d.axes)
        n = 1
        for dim, sp in zip(d.shape, tuple(spec) + (None,) * (len(d.shape) - len(spec))):
            if sp is None:
                n *= dim
                continue
            axes = (sp,) if isinstance(sp, str) else sp
            k = 1
            for a in axes:
                k *= mesh_shape[a]
            n *= -(-dim // k)
        total += n * dtype_of(d.dtype).dtype.itemsize
    return total


def _iter_defs(tree, prefix=()):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _iter_defs(v, prefix + (k,))
    elif isinstance(tree, (list, tuple)) and not is_def(tree):
        for i, v in enumerate(tree):
            yield from _iter_defs(v, prefix + (i,))
    else:
        yield prefix, tree
