"""Token embedding lookups.

`mapsin` path: the paper's technique as a first-class LM feature. The table
is vocab-sharded over the `model` axis (a distributed sorted index, row key =
token id); lookups ship *token ids* to the owner shard and *hit rows* back
(psum), instead of all-gathering the table — the map-side index nested-loop
join economy ("transfer only the data that is really needed", §4.1 of the
paper) applied to embeddings. For decode steps this replaces an O(vocab * d)
gather with O(new_tokens * d) traffic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def dense_embed(table: jax.Array, tokens: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def mapsin_embed(table: jax.Array, tokens: jax.Array, mesh, rules) -> jax.Array:
    """table: (v, d) sharded P('model', ...); tokens: (b, s) sharded on batch.

    Each model-axis shard resolves the token ids that fall in its local vocab
    range (an HBase-region GET against its sorted local index) and the psum
    routes only the resolved d-vectors back.
    """
    if mesh is None or "model" not in mesh.axis_names:
        return dense_embed(table, tokens)
    msize = mesh.shape["model"]
    v = table.shape[0]
    if v % msize:
        return dense_embed(table, tokens)
    vloc = v // msize
    # Token ids are 4 B each — replicating them into the shard_map is
    # negligible traffic; only table rows (the heavy side) stay sharded.
    n_tok_dims = tokens.ndim
    tok_spec = P(*([None] * n_tok_dims))
    tbl_spec = P("model", None)
    out_spec = P(*([None] * (n_tok_dims + 1)))

    def f(tbl, tok):
        lo = jax.lax.axis_index("model") * vloc
        local = tok - lo
        hit = (local >= 0) & (local < vloc)
        rows = jnp.take(tbl, jnp.clip(local, 0, vloc - 1), axis=0)
        rows = rows * hit[..., None].astype(rows.dtype)
        return jax.lax.psum(rows, axis_name="model")

    return shard_map(f, mesh=mesh, in_specs=(tbl_spec, tok_spec),
                     out_specs=out_spec, check_rep=False)(table, tokens)


def embed(table: jax.Array, tokens: jax.Array, impl: str, mesh=None,
          rules=None) -> jax.Array:
    if impl == "mapsin":
        return mapsin_embed(table, tokens, mesh, rules)
    return dense_embed(table, tokens)
