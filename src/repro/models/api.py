"""Uniform model API: build_model, input defs per shape, step factories.

Every launcher (train.py, serve.py, dryrun.py) goes through this module so
all 10 architectures expose identical entry points:

    train_step(params, opt_state, batch)        -> (params, opt_state, metrics)
    prefill_step(params, batch)                 -> (logits, cache)
    decode_step(params, cache, tokens)          -> (logits, cache)
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.params import pdef
from repro.models.recurrent import RecurrentGemmaLM
from repro.models.transformer import VIT_DIM, TransformerLM
from repro.models.xlstm import XLSTMLM
from repro.optim.adamw import OptConfig, adamw_update


def build_model(cfg: ModelConfig, mesh=None, rules=None):
    if cfg.family == "ssm":
        return XLSTMLM(cfg, mesh, rules)
    if cfg.family == "hybrid":
        return RecurrentGemmaLM(cfg, mesh, rules)
    return TransformerLM(cfg, mesh, rules)


def input_defs(cfg: ModelConfig, shape: ShapeConfig,
               micro_batches: int = 1) -> dict[str, Any]:
    """ParamDef tree for the step inputs of one (arch x shape) cell.

    With micro_batches > 1, train inputs carry a leading (unsharded)
    microbatch dim: (n_micro, rows, seq) — the host pipeline pre-shapes, so
    no resharding happens inside the step (see make_train_step).

    Modality frontends are STUBS per assignment: pixtral receives
    precomputed ViT patch embeddings, musicgen precomputed EnCodec codes.
    """
    b = shape.global_batch
    s = shape.seq_len
    kind = shape.kind
    tok_axes: tuple = ("batch", "seq")
    lead: tuple[int, ...] = ()
    lead_axes: tuple = ()
    if kind == "train" and micro_batches > 1:
        assert b % micro_batches == 0
        b = b // micro_batches
        lead, lead_axes = (micro_batches,), (None,)
    if kind == "decode":
        if cfg.family == "audio":
            return {"tokens": pdef((b, 1, cfg.num_codebooks),
                                   tok_axes + (None,), "int32", "zeros")}
        return {"tokens": pdef((b, 1), tok_axes, "int32", "zeros")}
    out: dict[str, Any] = {}
    if cfg.family == "vlm":
        s_text = s - cfg.num_patches
        out["tokens"] = pdef(lead + (b, s_text), lead_axes + tok_axes, "int32", "zeros")
        out["patch_embeds"] = pdef(lead + (b, cfg.num_patches, VIT_DIM),
                                   lead_axes + ("batch", None, None),
                                   cfg.activation_dtype, "zeros")
        if kind == "train":
            out["labels"] = pdef(lead + (b, s_text), lead_axes + tok_axes, "int32", "zeros")
        return out
    if cfg.family == "audio":
        out["tokens"] = pdef(lead + (b, s, cfg.num_codebooks),
                             lead_axes + tok_axes + (None,), "int32", "zeros")
        if kind == "train":
            out["labels"] = pdef(lead + (b, s, cfg.num_codebooks),
                                 lead_axes + tok_axes + (None,), "int32", "zeros")
        return out
    out["tokens"] = pdef(lead + (b, s), lead_axes + tok_axes, "int32", "zeros")
    if kind == "train":
        out["labels"] = pdef(lead + (b, s), lead_axes + tok_axes, "int32", "zeros")
    return out


def default_micro_batches(cfg: ModelConfig, shape: ShapeConfig, mesh) -> int:
    """Pick the microbatch count so the per-microbatch remat stash
    (L x rows_local x seq x d_model, bf16) stays ~<= 2 GiB/chip."""
    if shape.kind != "train" or mesh is None:
        return 1
    dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    best = 1
    for n in range(1, shape.global_batch + 1):
        rows = shape.global_batch // n
        # microbatch rows must stay evenly DP-shardable
        if shape.global_batch % n or rows % dp or rows < dp:
            continue
        rows_local = rows // dp
        stash = cfg.num_layers * rows_local * shape.seq_len * cfg.d_model * 2
        best = n
        if stash <= 2 * 2**30:
            break
    return best


def make_train_step(model, opt_cfg: OptConfig, micro_batches: int = 1,
                    accum_dtype=None):
    """Grad-accumulating train step. The microbatch loop is a non-
    differentiated lax.scan, so activation memory = ONE microbatch's remat
    stash; gradients accumulate in a params-sharded carry (fp32 by default;
    bf16 for memory-floor models — tracked as 'gradient compression')."""
    accum_dtype = accum_dtype or jnp.float32
    def grads_of(params, batch):
        def loss_fn(p):
            return model.loss(p, batch)
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        return loss, metrics, grads

    def train_step(params, opt_state, batch):
        if micro_batches == 1:
            loss, metrics, grads = grads_of(params, batch)
        else:
            def body(acc, micro):
                loss, metrics, grads = grads_of(params, micro)
                acc = jax.tree.map(lambda a, g: a + g.astype(accum_dtype) / micro_batches,
                                   acc, grads)
                return acc, (loss, metrics)
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), params)
            grads, (losses, metricses) = jax.lax.scan(body, zeros, batch)
            loss = losses.mean()
            metrics = jax.tree.map(lambda m: m.mean(), metricses)
        params, opt_state, opt_metrics = adamw_update(grads, opt_state, params, opt_cfg)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics
    return train_step


def make_prefill_step(model):
    def prefill_step(params, batch):
        return model.prefill(params, batch)
    return prefill_step


def make_decode_step(model):
    def decode_step(params, cache, batch):
        return model.decode_step(params, cache, batch["tokens"])
    return decode_step
