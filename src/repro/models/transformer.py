"""TransformerLM: dense / MoE / VLM / audio decoder architectures.

One composable implementation covers deepseek-7b, yi-6b/34b, qwen3-8b
(qk-norm), deepseek-v3 (MLA + MoE + MTP), dbrx (MoE), pixtral (VLM backbone,
stub ViT frontend) and musicgen (audio backbone, stub EnCodec frontend).

Structure: pre-norm blocks, scan-over-layers with stacked params (compile
time independent of depth), chunked-vocab CE loss, KV-cache prefill/decode.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.common import dtype_of
from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import embedding as embed_lib
from repro.models.layers import apply_rope, rms_norm, softmax_xent_chunked, swiglu
from repro.models.moe import moe_ffn
from repro.models.params import ParamDef, pdef, stack_defs

VIT_DIM = 1024  # pixtral ViT stub output width


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "minimal":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    if policy == "names":
        # save ONLY the (sequence-sharded, bf16) per-layer input; recompute
        # everything else in backward. See DESIGN.md §4 memory plan.
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.save_only_these_names("layer_in"))
    return jax.checkpoint(fn)  # full


class TransformerLM:
    def __init__(self, cfg: ModelConfig, mesh=None, rules=None):
        self.cfg = cfg
        self.mesh = mesh
        self.rules = rules
        self.adt = dtype_of(cfg.activation_dtype)

    # ------------------------------------------------------------------
    # Parameter definitions
    # ------------------------------------------------------------------
    def _attn_defs(self) -> dict[str, ParamDef]:
        c = self.cfg
        d, h, g, e = c.d_model, c.num_heads, c.num_kv_heads, c.resolved_head_dim
        pd = c.param_dtype
        if c.use_mla:
            dn, dr, dv = c.qk_nope_head_dim, c.qk_rope_head_dim, c.v_head_dim
            out = {
                "norm": pdef((d,), ("embed",), pd, "ones"),
                "q_a": pdef((d, c.q_lora_rank), ("fsdp", "q_lora"), pd),
                "q_norm": pdef((c.q_lora_rank,), ("q_lora",), pd, "ones"),
                "q_b": pdef((c.q_lora_rank, h, dn + dr), ("q_lora", "heads", None), pd),
                "kv_a": pdef((d, c.kv_lora_rank + dr), ("fsdp", None), pd),
                "kv_norm": pdef((c.kv_lora_rank,), ("kv_lora",), pd, "ones"),
                "kv_b_k": pdef((c.kv_lora_rank, h, dn), ("kv_lora", "heads", None), pd),
                "kv_b_v": pdef((c.kv_lora_rank, h, dv), ("kv_lora", "heads", None), pd),
                "wo": pdef((h, dv, d), ("heads", None, "fsdp"), pd),
            }
            return out
        out = {
            "norm": pdef((d,), ("embed",), pd, "ones"),
            "wq": pdef((d, h, e), ("fsdp", "heads", "head_dim"), pd),
            "wk": pdef((d, g, e), ("fsdp", "kv_heads", "head_dim"), pd),
            "wv": pdef((d, g, e), ("fsdp", "kv_heads", "head_dim"), pd),
            "wo": pdef((h, e, d), ("heads", "head_dim", "fsdp"), pd),
        }
        if c.qk_norm:
            out["qn"] = pdef((e,), ("head_dim",), pd, "ones")
            out["kn"] = pdef((e,), ("head_dim",), pd, "ones")
        return out

    def _mlp_defs(self, d_ff: int) -> dict[str, ParamDef]:
        c = self.cfg
        d, pd = c.d_model, c.param_dtype
        return {
            "norm": pdef((d,), ("embed",), pd, "ones"),
            "w_gate": pdef((d, d_ff), ("fsdp", "mlp"), pd),
            "w_up": pdef((d, d_ff), ("fsdp", "mlp"), pd),
            "w_down": pdef((d_ff, d), ("mlp", "fsdp"), pd),
        }

    def _moe_defs(self) -> dict[str, ParamDef]:
        c = self.cfg
        d, pd = c.d_model, c.param_dtype
        e, f = c.num_experts, c.moe_d_ff
        out = {
            "norm": pdef((d,), ("embed",), pd, "ones"),
            "router": pdef((d, e), ("embed", "experts"), "float32"),
            # f carries "mlp": when EP only covers part of the mesh (dbrx:
            # 16 experts -> data axis), d_ff TP-shards over the rest — expert
            # weights end up fully sharded, zero FSDP gathers
            "w_gate": pdef((e, d, f), ("experts", "fsdp", "mlp"), pd),
            "w_up": pdef((e, d, f), ("experts", "fsdp", "mlp"), pd),
            "w_down": pdef((e, f, d), ("experts", "mlp", "fsdp"), pd),
        }
        if c.num_shared_experts:
            fs = f * c.num_shared_experts
            out["shared_w_gate"] = pdef((d, fs), ("fsdp", "mlp"), pd)
            out["shared_w_up"] = pdef((d, fs), ("fsdp", "mlp"), pd)
            out["shared_w_down"] = pdef((fs, d), ("mlp", "fsdp"), pd)
        return out

    def _block_defs(self, moe: bool) -> dict[str, Any]:
        mix = self._moe_defs() if moe else self._mlp_defs(self.cfg.dense_d_ff or self.cfg.d_ff)
        return {"attn": self._attn_defs(), "mlp": mix}

    def param_defs(self) -> dict[str, Any]:
        c = self.cfg
        d, v, pd = c.d_model, c.vocab_size, c.param_dtype
        defs: dict[str, Any] = {}
        if c.family == "audio":
            defs["embed"] = pdef((c.num_codebooks, v, d), ("stack", "vocab", "fsdp"), pd)
        else:
            defs["embed"] = pdef((v, d), ("vocab", "fsdp"), pd)
        if c.family == "vlm":
            defs["patch_proj"] = pdef((VIT_DIM, d), ("embed", "fsdp"), pd)
        n_dense = c.first_dense_layers if c.num_experts else c.num_layers
        n_moe = c.num_layers - n_dense if c.num_experts else 0
        if n_dense:
            defs["dense_layers"] = stack_defs(self._block_defs(False), n_dense)
        if n_moe:
            defs["moe_layers"] = stack_defs(self._block_defs(True), n_moe)
        defs["final_norm"] = pdef((d,), ("embed",), pd, "ones")
        if c.family == "audio":
            defs["lm_head"] = pdef((c.num_codebooks, d, v), ("stack", "embed", "vocab"), pd)
        elif not c.tie_embeddings:
            defs["lm_head"] = pdef((d, v), ("embed", "vocab"), pd)
        if c.mtp_depth:
            defs["mtp"] = {
                "norm1": pdef((d,), ("embed",), pd, "ones"),
                "norm2": pdef((d,), ("embed",), pd, "ones"),
                "proj": pdef((2 * d, d), ("fsdp", "embed"), pd),
                "block": self._block_defs(bool(c.num_experts)),
            }
        return defs

    # ------------------------------------------------------------------
    # Blocks
    # ------------------------------------------------------------------
    def _constrain(self, x, *axes):
        if self.rules is not None and self.mesh is not None:
            x = jax.lax.with_sharding_constraint(x, self.rules.sharding(*axes))
        return x

    def _gqa_attention(self, p, x, positions, *, mode, cache=None, cur_len=None):
        c = self.cfg
        eps = c.norm_eps
        xs = rms_norm(x, p["norm"], eps)
        q = jnp.einsum("bsd,dhe->bshe", xs, p["wq"])
        k = jnp.einsum("bsd,dge->bsge", xs, p["wk"])
        v = jnp.einsum("bsd,dge->bsge", xs, p["wv"])
        if c.qk_norm:
            q = rms_norm(q, p["qn"], eps)
            k = rms_norm(k, p["kn"], eps)
        q = apply_rope(q, positions, c.rope_theta)
        k = apply_rope(k, positions, c.rope_theta)
        q = self._constrain(q, "batch", "seq", "heads", "head_dim")
        k = self._constrain(k, "batch", "seq", "kv_heads", "head_dim")
        if mode == "decode":
            kc, vc = cache  # (b, S, g, e) — possibly quantized (fp8)
            cdt = dtype_of(c.kv_cache_dtype)
            S = kc.shape[1]
            if c.window_size and S == c.window_size:
                idx = cur_len % c.window_size  # rotating window cache
            else:
                idx = jnp.minimum(cur_len, S - 1)
            kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(cdt), idx, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(cdt), idx, axis=1)
            o = attn_lib.decode_attention(q, kc.astype(self.adt),
                                          vc.astype(self.adt), cur_len + 1,
                                          window=c.window_size)
            new_cache = (kc, vc)
        else:
            o = attn_lib.attention(
                q, k, v, impl=c.attention_impl, causal=True,
                window=c.window_size, block_q=c.attn_block_q,
                block_kv=c.attn_block_kv)
            new_cache = (k, v) if mode == "prefill" else None
        out = jnp.einsum("bshe,hed->bsd", o, p["wo"])
        return x + out, new_cache

    def _mla_attention(self, p, x, positions, *, mode, cache=None, cur_len=None):
        c = self.cfg
        eps = c.norm_eps
        dn, dr, dv = c.qk_nope_head_dim, c.qk_rope_head_dim, c.v_head_dim
        h = c.num_heads
        xs = rms_norm(x, p["norm"], eps)
        cq = rms_norm(jnp.einsum("bsd,dq->bsq", xs, p["q_a"]), p["q_norm"], eps)
        q = jnp.einsum("bsq,qhe->bshe", cq, p["q_b"])
        q_nope, q_pe = q[..., :dn], q[..., dn:]
        q_pe = apply_rope(q_pe, positions, c.rope_theta)
        kv = jnp.einsum("bsd,dk->bsk", xs, p["kv_a"])
        ckv, k_pe = kv[..., :c.kv_lora_rank], kv[..., c.kv_lora_rank:]
        ckv = rms_norm(ckv, p["kv_norm"], eps)
        k_pe = apply_rope(k_pe[:, :, None, :], positions, c.rope_theta)[:, :, 0]
        scale = (dn + dr) ** -0.5
        if mode == "decode":
            ckv_c, kpe_c = cache  # (b, S, c), (b, S, dr) — possibly fp8
            cdt = dtype_of(c.kv_cache_dtype)
            S = ckv_c.shape[1]
            idx = jnp.minimum(cur_len, S - 1)
            ckv_c = jax.lax.dynamic_update_slice_in_dim(ckv_c, ckv.astype(cdt), idx, axis=1)
            kpe_c = jax.lax.dynamic_update_slice_in_dim(kpe_c, k_pe.astype(cdt), idx, axis=1)
            o = attn_lib.mla_absorbed_decode(
                q_nope[:, 0], q_pe[:, 0], ckv_c.astype(self.adt),
                kpe_c.astype(self.adt),
                p["kv_b_k"], p["kv_b_v"], cur_len + 1, scale=scale)
            o = o[:, None]  # (b, 1, h, dv)
            new_cache = (ckv_c, kpe_c)
        else:
            qq = jnp.concatenate([q_nope, q_pe], -1)
            qq = self._constrain(qq, "batch", "seq", "heads", None)
            if c.attention_impl == "naive":
                kvup = jnp.einsum("bsk,khe->bshe",
                                  ckv, jnp.concatenate([p["kv_b_k"], p["kv_b_v"]], -1))
                k_nope, v = kvup[..., :dn], kvup[..., dn:]
                k = jnp.concatenate(
                    [k_nope, jnp.broadcast_to(k_pe[:, :, None, :], q_pe.shape)], -1)
                o = attn_lib.attention(qq, k, v, impl="naive", causal=True,
                                       scale=scale)
            else:
                # latent-blockwise: never materializes full per-head K/V
                o = attn_lib.mla_prefill_attention(
                    qq, ckv, k_pe, p["kv_b_k"], p["kv_b_v"], scale=scale,
                    block_q=c.attn_block_q, block_kv=c.attn_block_kv)
            new_cache = (ckv, k_pe) if mode == "prefill" else None
        out = jnp.einsum("bshv,hvd->bsd", o, p["wo"])
        return x + out, new_cache

    def _mix(self, p, x, positions, *, mode, cache=None, cur_len=None):
        if self.cfg.use_mla:
            return self._mla_attention(p, x, positions, mode=mode, cache=cache,
                                       cur_len=cur_len)
        return self._gqa_attention(p, x, positions, mode=mode, cache=cache,
                                   cur_len=cur_len)

    def _ffn(self, p, x, moe: bool):
        c = self.cfg
        xs = rms_norm(x, p["norm"], c.norm_eps)
        if moe:
            b, s, d = xs.shape
            y, aux, dropped = moe_ffn(xs.reshape(b * s, d), p, top_k=c.top_k,
                                      num_experts=c.num_experts,
                                      capacity_factor=c.capacity_factor,
                                      constrain=self._constrain)
            return x + y.reshape(b, s, d), aux
        return x + swiglu(xs, p["w_gate"], p["w_up"], p["w_down"]), jnp.float32(0)

    def _block(self, p, x, positions, moe: bool, *, mode, cache=None, cur_len=None):
        if mode == "train":
            # Megatron-style sequence parallelism for the activation residual:
            # the layer-scan carry (== the only cross-layer saved activation
            # under full remat) stays sharded (batch x model-on-seq); each
            # layer gathers it, computes, and re-scatters its output.
            # Saved-activation HBM drops by the TP degree for an extra
            # per-layer all-gather (memory <-> collective trade, quantified
            # in EXPERIMENTS.md §Perf).
            x = checkpoint_name(x, "layer_in")
        x = self._constrain(x, "batch", "seq", "embed")
        x, new_cache = self._mix(p["attn"], x, positions, mode=mode,
                                 cache=cache, cur_len=cur_len)
        x, aux = self._ffn(p["mlp"], x, moe)
        if mode == "train":
            x = self._constrain(x, "batch", "seq_ckpt", "embed")
        return x, new_cache, aux

    # ------------------------------------------------------------------
    # Embedding / head
    # ------------------------------------------------------------------
    def _embed_tokens(self, params, tokens):
        c = self.cfg
        if c.family == "audio":
            # tokens: (b, s, K) — sum of per-codebook embeddings
            parts = [embed_lib.embed(params["embed"][k], tokens[..., k],
                                     c.embedding_impl, self.mesh, self.rules)
                     for k in range(c.num_codebooks)]
            return functools.reduce(jnp.add, parts).astype(self.adt)
        return embed_lib.embed(params["embed"], tokens, c.embedding_impl,
                               self.mesh, self.rules).astype(self.adt)

    def _head_w(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["lm_head"]

    # ------------------------------------------------------------------
    # Forward passes
    # ------------------------------------------------------------------
    def _stack(self, params, x, positions, *, mode, cache=None, cur_len=None):
        """Run all blocks; returns (x, new_caches, aux_sum)."""
        c = self.cfg
        aux_total = jnp.float32(0)
        new_caches: dict[str, Any] = {}

        for group, moe in (("dense_layers", False), ("moe_layers", True)):
            if group not in params:
                continue
            stacked = params[group]

            def body(carry, xs, moe=moe):
                x, aux = carry
                if mode == "train":
                    p = xs
                    blk = _remat(functools.partial(self._block, moe=moe, mode=mode),
                                 c.remat_policy)
                    x, _, a = blk(p, x, positions)
                    return (x, aux + a), None
                p, cch = xs
                x, ncch, a = self._block(p, x, positions, moe, mode=mode,
                                         cache=cch, cur_len=cur_len)
                return (x, aux + a), ncch

            if not c.scan_layers and mode == "train":
                # unrolled: exact XLA cost analysis (calibration mode)
                n = jax.tree.leaves(stacked)[0].shape[0]
                for i in range(n):
                    p_i = jax.tree.map(lambda t: t[i], stacked)
                    (x, aux_total), _ = body((x, aux_total), p_i)
            elif mode == "train":
                (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), stacked)
            else:
                (x, aux_total), ncc = jax.lax.scan(
                    body, (x, aux_total), (stacked, cache[group]))
                new_caches[group] = ncc
        return x, new_caches, aux_total

    def loss(self, params, batch):
        """batch: tokens (b, s[, K]), labels (b, s[, K]), optional patch_embeds."""
        c = self.cfg
        tokens, labels = batch["tokens"], batch["labels"]
        x = self._embed_tokens(params, tokens)
        n_prefix = 0
        if c.family == "vlm":
            patches = jnp.einsum("bpv,vd->bpd",
                                 batch["patch_embeds"].astype(self.adt),
                                 params["patch_proj"]).astype(self.adt)
            x = jnp.concatenate([patches, x], axis=1)
            n_prefix = patches.shape[1]
        positions = jnp.arange(x.shape[1])[None]
        x = self._constrain(x, "batch", "seq_ckpt", "embed")
        x, _, aux = self._stack(params, x, positions, mode="train")
        x = self._constrain(x, "batch", "seq", "embed")
        h = rms_norm(x, params["final_norm"], c.norm_eps)
        if n_prefix:
            h = h[:, n_prefix:]
        mask = (labels >= 0).astype(jnp.float32)
        if c.family == "audio":
            head = params["lm_head"]  # (K, d, v)
            tot = jnp.float32(0)
            for k in range(c.num_codebooks):
                tot = tot + softmax_xent_chunked(h, head[k], labels[..., k],
                                                 mask[..., k])
            ce = tot / c.num_codebooks
        else:
            ce = softmax_xent_chunked(h, self._head_w(params), labels, mask)
        metrics = {"ce": ce, "aux": aux}
        loss = ce + c.router_aux_weight * aux
        if c.mtp_depth:
            mtp_ce = self._mtp_loss(params, x, tokens, labels)
            metrics["mtp_ce"] = mtp_ce
            loss = loss + 0.1 * mtp_ce
        return loss, metrics

    def _mtp_loss(self, params, hidden, tokens, labels):
        """DeepSeek-V3 multi-token prediction (depth 1): predict t+2 from the
        main trunk's h_t fused with the embedding of token t+1."""
        c = self.cfg
        p = params["mtp"]
        h = rms_norm(hidden[:, :-1], p["norm1"], c.norm_eps)
        e = rms_norm(self._embed_tokens(params, tokens[:, 1:]), p["norm2"], c.norm_eps)
        x = jnp.einsum("bsd,dk->bsk", jnp.concatenate([h, e], -1), p["proj"])
        positions = jnp.arange(x.shape[1])[None]
        x, _, _ = self._block(p["block"], x, positions,
                              moe=bool(c.num_experts), mode="train")
        hh = rms_norm(x, params["final_norm"], c.norm_eps)
        lab = labels[:, 1:]  # labels are already t+1 targets; shift once more
        mask = (lab >= 0).astype(jnp.float32)
        return softmax_xent_chunked(hh, self._head_w(params), lab, mask)

    # ------------------------------------------------------------------
    # Serving
    # ------------------------------------------------------------------
    def cache_defs(self, batch: int, seq_len: int) -> dict[str, Any]:
        c = self.cfg
        dt = c.kv_cache_dtype
        S = min(seq_len, c.window_size) if (c.window_size and c.window_size < seq_len) else seq_len
        if c.use_mla:
            # latent cache: shard the sequence dim over `model` (no head dim
            # exists to split); softmax/psum handles the sharded reduction
            per = (pdef((batch, S, c.kv_lora_rank), ("batch", "seq_kv", "kv_lora"), dt, "zeros"),
                   pdef((batch, S, c.qk_rope_head_dim), ("batch", "seq_kv", "rope"), dt, "zeros"))
        else:
            g, e = c.num_kv_heads, c.resolved_head_dim
            per = (pdef((batch, S, g, e), ("batch", None, "kv_heads", "head_dim"), dt, "zeros"),
                   pdef((batch, S, g, e), ("batch", None, "kv_heads", "head_dim"), dt, "zeros"))
        defs: dict[str, Any] = {}
        n_dense = c.first_dense_layers if c.num_experts else c.num_layers
        n_moe = c.num_layers - n_dense if c.num_experts else 0
        if n_dense:
            defs["dense_layers"] = stack_defs(per, n_dense)
        if n_moe:
            defs["moe_layers"] = stack_defs(per, n_moe)
        defs["cur_len"] = pdef((), (), "int32", "zeros")
        return defs

    def prefill(self, params, batch, margin: int = 64):
        c = self.cfg
        tokens = batch["tokens"]
        x = self._embed_tokens(params, tokens)
        n_prefix = 0
        if c.family == "vlm":
            patches = jnp.einsum("bpv,vd->bpd",
                                 batch["patch_embeds"].astype(self.adt),
                                 params["patch_proj"]).astype(self.adt)
            x = jnp.concatenate([patches, x], axis=1)
            n_prefix = patches.shape[1]
        positions = jnp.arange(x.shape[1])[None]
        # run blocks in prefill mode, capturing caches via scan ys
        seq = x.shape[1]
        caches: dict[str, Any] = {}
        aux = jnp.float32(0)

        for group, moe in (("dense_layers", False), ("moe_layers", True)):
            if group not in params:
                continue

            def body(carry, p, moe=moe):
                x, aux = carry
                x, cch, a = self._block(p, x, positions, moe, mode="prefill")
                return (x, aux + a), cch

            (x, aux), cch = jax.lax.scan(body, (x, aux), params[group])
            if c.window_size and c.window_size < seq:
                cch = tuple(z[:, :, -c.window_size:] for z in cch)
            elif margin:
                # decode headroom: without it the first generated token's kv
                # would overwrite the last prompt position
                cch = tuple(jnp.pad(z, ((0, 0), (0, 0), (0, margin))
                                    + ((0, 0),) * (z.ndim - 3)) for z in cch)
            cdt = dtype_of(c.kv_cache_dtype)
            caches[group] = tuple(z.astype(cdt) for z in cch)
        h = rms_norm(x[:, -1:], params["final_norm"], c.norm_eps)
        logits = self._last_logits(params, h)
        caches["cur_len"] = jnp.int32(seq)
        return logits, caches

    def _last_logits(self, params, h):
        c = self.cfg
        if c.family == "audio":
            return jnp.einsum("bsd,kdv->bskv", h, params["lm_head"])[:, 0]
        return jnp.einsum("bsd,dv->bsv", h, self._head_w(params))[:, 0]

    def decode_step(self, params, cache, tokens):
        """tokens: (b, 1[, K]) — one new token given an existing cache."""
        c = self.cfg
        cur = cache["cur_len"]
        x = self._embed_tokens(params, tokens)
        positions = jnp.full((1, 1), cur, jnp.int32)
        new_cache: dict[str, Any] = {"cur_len": cur + 1}
        x = self._constrain(x, "batch", "seq", "embed")
        for group, moe in (("dense_layers", False), ("moe_layers", True)):
            if group not in params:
                continue

            def body(carry, xs, moe=moe):
                x = carry
                p, cch = xs
                x, ncch, _ = self._block(p, x, positions, moe, mode="decode",
                                         cache=cch, cur_len=cur)
                return x, ncch

            x, ncc = jax.lax.scan(body, x, (params[group], cache[group]))
            new_cache[group] = ncc
        h = rms_norm(x, params["final_norm"], c.norm_eps)
        logits = self._last_logits(params, h)
        return logits, new_cache
