"""Deterministic, stateless synthetic LM data pipeline.

Tokens are a pure function of (seed, step, row, position) via a counter-mode
integer hash — no files, no iterator state. That makes fault-tolerant
restart trivial (re-derive any batch from the step index, bit-exact) and
lets every data-parallel host slice exactly its rows with zero coordination.
A Zipf-ish transform keeps the token histogram realistic so vocab-sharded
embedding paths (MAPSIN lookups) see skewed traffic like real text.
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


def _hash64(x: np.ndarray) -> np.ndarray:
    """splitmix64 — counter-mode PRNG, vectorized."""
    x = (x + np.uint64(0x9E3779B97F4A7C15)).astype(np.uint64)
    x ^= x >> np.uint64(30)
    x = (x * np.uint64(0xBF58476D1CE4E5B9)).astype(np.uint64)
    x ^= x >> np.uint64(27)
    x = (x * np.uint64(0x94D049BB133111EB)).astype(np.uint64)
    return x ^ (x >> np.uint64(31))


def tokens_for(seed: int, step: int, rows: np.ndarray, seq_len: int,
               vocab: int) -> np.ndarray:
    """(len(rows), seq_len) int32 tokens; `rows` are global batch indices."""
    pos = np.arange(seq_len + 1, dtype=np.uint64)
    ctr = (np.uint64(seed) << np.uint64(48)) ^ (np.uint64(step) << np.uint64(24))
    grid = ctr ^ (rows.astype(np.uint64)[:, None] << np.uint64(40)) ^ pos[None]
    h = _hash64(grid)
    u = (h >> np.uint64(11)).astype(np.float64) / float(1 << 53)
    # Zipf-ish skew: id = vocab * u^3 concentrates mass on small ids
    ids = np.minimum((vocab * u ** 3).astype(np.int64), vocab - 1)
    return ids.astype(np.int32)


def batch_for_step(cfg: ModelConfig, shape: ShapeConfig, step: int,
                   seed: int = 0, rows: np.ndarray | None = None) -> dict:
    """Full (or row-sliced) batch for `step`. labels = next-token targets."""
    if rows is None:
        rows = np.arange(shape.global_batch)
    batch: dict = {}
    if cfg.family == "vlm":
        s_text = shape.seq_len - cfg.num_patches
        t = tokens_for(seed, step, rows, s_text, cfg.vocab_size)  # (b, s_text+1)
        batch["tokens"] = t[:, :-1]
        batch["labels"] = t[:, 1:].copy()
        pe = _hash64((np.uint64(seed + 7) << np.uint64(32))
                     ^ np.arange(len(rows) * cfg.num_patches * 16,
                                 dtype=np.uint64))
        pe = (pe.astype(np.float64) / 2**64 - 0.5).astype(np.float32)
        # cheap deterministic patch embeddings (stub ViT output, dim 1024)
        base = pe.reshape(len(rows), cfg.num_patches, 16)
        batch["patch_embeds"] = np.tile(base, (1, 1, 64)).astype(np.float32)
    elif cfg.family == "audio":
        k = cfg.num_codebooks
        t = np.stack([tokens_for(seed + c, step, rows, shape.seq_len,
                                 cfg.vocab_size) for c in range(k)], -1)
        batch["tokens"] = t[:, :-1]
        batch["labels"] = t[:, 1:].copy()
    else:
        t = tokens_for(seed, step, rows, shape.seq_len, cfg.vocab_size)
        batch["tokens"] = t[:, :-1]
        batch["labels"] = t[:, 1:].copy()
    return batch
