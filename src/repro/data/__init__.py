from repro.data.lm_data import batch_for_step, tokens_for  # noqa: F401
from repro.data.rdf_gen import lubm_like, sp2b_like  # noqa: F401
