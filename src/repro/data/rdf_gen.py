"""Synthetic RDF generators mirroring the paper's benchmarks.

`lubm_like(n_universities)` — university/department/professor/student graph
with the LUBM schema subset the paper's queries touch; selectivities mirror
LUBM's (point lookups on a named department vs. broad class scans).

`sp2b_like(scale)` — DBLP-style articles/inproceedings with author/cite
structure; less selective queries, like SP²Bench.

Both return (triples (N,3) int32, Dictionary, {query name: [Pattern, ...]})
with query sets matching the paper's evaluation tables (Appendix A/B).
"""
from __future__ import annotations

import numpy as np

from repro.core.rdf import Dictionary, Pattern

RDF_TYPE = "rdf:type"


def _p(d: Dictionary, s: str, p: str, o: str, out: list):
    out.append((d.id(s), d.id(p), d.id(o)))


def lubm_like(n_universities: int, seed: int = 0):
    rng = np.random.RandomState(seed)
    d = Dictionary()
    t: list[tuple[int, int, int]] = []
    n_dept, n_prof, n_stud, n_course = 12, 18, 120, 24
    for u in range(n_universities):
        uni = f"Univ{u}"
        _p(d, uni, RDF_TYPE, "University", t)
        for dep in range(n_dept):
            dept = f"Dept{dep}.U{u}"
            _p(d, dept, RDF_TYPE, "Department", t)
            _p(d, dept, "subOrganizationOf", uni, t)
            rg = f"ResearchGroup{dep}.U{u}"
            _p(d, rg, RDF_TYPE, "ResearchGroup", t)
            _p(d, rg, "subOrganizationOf", uni, t)
            courses = [f"Course{c}.D{dep}.U{u}" for c in range(n_course)]
            for c in courses:
                _p(d, c, RDF_TYPE, "Course", t)
            profs = []
            for pr in range(n_prof):
                kind = ("FullProfessor", "AssociateProfessor",
                        "AssistantProfessor")[pr % 3]
                prof = f"Prof{pr}.D{dep}.U{u}"
                profs.append(prof)
                _p(d, prof, RDF_TYPE, kind, t)
                _p(d, prof, RDF_TYPE, "Professor", t)
                _p(d, prof, "worksFor", dept, t)
                _p(d, prof, "name", f"name.{prof}", t)
                _p(d, prof, "emailAddress", f"email.{prof}", t)
                _p(d, prof, "telephone", f"tel.{prof}", t)
                for c in rng.choice(n_course, 2, replace=False):
                    _p(d, prof, "teacherOf", courses[c], t)
                pub = f"Publication{pr}.D{dep}.U{u}"
                _p(d, pub, RDF_TYPE, "Publication", t)
                _p(d, pub, "publicationAuthor", prof, t)
            for st in range(n_stud):
                kind = "GraduateStudent" if st % 5 == 0 else "UndergraduateStudent"
                stud = f"Student{st}.D{dep}.U{u}"
                _p(d, stud, RDF_TYPE, kind, t)
                _p(d, stud, RDF_TYPE, "Student", t)
                _p(d, stud, "memberOf", dept, t)
                _p(d, stud, "emailAddress", f"email.{stud}", t)
                for c in rng.choice(n_course, 3, replace=False):
                    _p(d, stud, "takesCourse", courses[c], t)
                if st % 4 == 0:
                    _p(d, stud, "advisor", profs[st % n_prof], t)
    triples = np.array(t, np.int32)

    q = d.pattern
    queries = {
        # Q1: selective point join — students taking a given course
        "Q1": [q("?x", RDF_TYPE, "GraduateStudent"),
               q("?x", "takesCourse", "Course0.D0.U0")],
        # Q3: publications of a given professor
        "Q3": [q("?x", RDF_TYPE, "Publication"),
               q("?x", "publicationAuthor", "Prof2.D0.U0")],
        # Q4: professor star — worksFor dept0 + name/email/tel (multiway)
        "Q4": [q("?x", RDF_TYPE, "Professor"),
               q("?x", "worksFor", "Dept0.U0"),
               q("?x", "name", "?y1"),
               q("?x", "emailAddress", "?y2"),
               q("?x", "telephone", "?y3")],
        # Q5: members of a given department
        "Q5": [q("?x", RDF_TYPE, "Student"),
               q("?x", "memberOf", "Dept0.U0")],
        # Q6: single-pattern class scan
        "Q6": [q("?x", RDF_TYPE, "Student")],
        # Q7: students taking a course of a given professor
        "Q7": [q("?y", RDF_TYPE, "Course"),
               q("Prof1.D0.U0", "teacherOf", "?y"),
               q("?x", "takesCourse", "?y"),
               q("?x", RDF_TYPE, "Student")],
        # Q8: students in departments of a given university, with email
        "Q8": [q("?y", RDF_TYPE, "Department"),
               q("?y", "subOrganizationOf", "Univ0"),
               q("?x", "memberOf", "?y"),
               q("?x", RDF_TYPE, "Student"),
               q("?x", "emailAddress", "?z")],
        # Q11: research groups of a given university
        "Q11": [q("?x", RDF_TYPE, "ResearchGroup"),
                q("?x", "subOrganizationOf", "Univ0")],
        # Q13: alumni-style — advisor edges of professors of Univ0's dept0
        "Q13": [q("?p", "worksFor", "Dept0.U0"),
                q("?x", "advisor", "?p")],
        # Q14: single-pattern broad scan
        "Q14": [q("?x", RDF_TYPE, "UndergraduateStudent")],
    }
    return triples, d, queries


# SPARQL text forms of the LUBM query set (serve/sparql.py round-trips
# these to exactly the hand-built Pattern lists above; constants are
# scale-independent — Dept0/Univ0/... exist at every n_universities >= 1)
_LUBM_HDR = "PREFIX rdf: <rdf:>\n"
LUBM_SPARQL = {
    "Q1": _LUBM_HDR + """SELECT ?x WHERE {
  ?x rdf:type <GraduateStudent> .
  ?x <takesCourse> <Course0.D0.U0> .
}""",
    "Q3": _LUBM_HDR + """SELECT ?x WHERE {
  ?x rdf:type <Publication> .
  ?x <publicationAuthor> <Prof2.D0.U0> .
}""",
    "Q4": _LUBM_HDR + """SELECT ?x ?y1 ?y2 ?y3 WHERE {
  ?x rdf:type <Professor> .
  ?x <worksFor> <Dept0.U0> .
  ?x <name> ?y1 .
  ?x <emailAddress> ?y2 .
  ?x <telephone> ?y3 .
}""",
    "Q5": _LUBM_HDR + """SELECT ?x WHERE {
  ?x rdf:type <Student> .
  ?x <memberOf> <Dept0.U0> .
}""",
    "Q6": _LUBM_HDR + "SELECT ?x WHERE { ?x rdf:type <Student> . }",
    "Q7": _LUBM_HDR + """SELECT ?x ?y WHERE {
  ?y rdf:type <Course> .
  <Prof1.D0.U0> <teacherOf> ?y .
  ?x <takesCourse> ?y .
  ?x rdf:type <Student> .
}""",
    "Q8": _LUBM_HDR + """SELECT ?x ?y ?z WHERE {
  ?y rdf:type <Department> .
  ?y <subOrganizationOf> <Univ0> .
  ?x <memberOf> ?y .
  ?x rdf:type <Student> .
  ?x <emailAddress> ?z .
}""",
    "Q11": _LUBM_HDR + """SELECT ?x WHERE {
  ?x rdf:type <ResearchGroup> .
  ?x <subOrganizationOf> <Univ0> .
}""",
    "Q13": _LUBM_HDR + """SELECT ?p ?x WHERE {
  ?p <worksFor> <Dept0.U0> .
  ?x <advisor> ?p .
}""",
    "Q14": _LUBM_HDR + "SELECT * WHERE { ?x a <UndergraduateStudent> . }",
}


def sp2b_like(n_articles: int, seed: int = 0):
    rng = np.random.RandomState(seed)
    d = Dictionary()
    t: list[tuple[int, int, int]] = []
    n_persons = max(n_articles // 3, 8)
    persons = [f"Person{i}" for i in range(n_persons)]
    n_proc = max(n_articles // 40, 2)
    for i in range(n_articles):
        kind = "Article" if i % 2 == 0 else "Inproceedings"
        a = f"Doc{i}"
        _p(d, a, RDF_TYPE, kind, t)
        _p(d, a, "dc:title", f"title{i}", t)
        _p(d, a, "dcterms:issued", f"year{1940 + (i % 70)}", t)
        for au in rng.choice(n_persons, 1 + (i % 3), replace=False):
            _p(d, a, "dc:creator", persons[au], t)
        if kind == "Inproceedings":
            _p(d, a, "bench:booktitle", f"book{i % 50}", t)
            _p(d, a, "dcterms:partOf", f"Proc{i % n_proc}", t)
            _p(d, a, "rdfs:seeAlso", f"see{i}", t)
            _p(d, a, "swrc:pages", f"pages{i % 300}", t)
            _p(d, a, "foaf:homepage", f"http://doc{i}", t)
        else:
            _p(d, a, "swrc:journal", f"Journal{i % 40}", t)
            if i % 4 == 0:
                _p(d, a, "swrc:pages", f"pages{i % 300}", t)
        for c in rng.choice(n_articles, min(2, i % 3), replace=False):
            _p(d, a, "dcterms:references", f"Doc{c}", t)
    triples = np.array(t, np.int32)

    q = d.pattern
    queries = {
        # Q1: year of a specific title (3 patterns, one join var — multiway)
        "Q1": [q("?a", RDF_TYPE, "Article"),
               q("?a", "dc:title", "title0"),
               q("?a", "dcterms:issued", "?yr")],
        # Q2: the big inproceedings star (9 patterns in the paper; 8 here —
        # OPTIONAL dropped exactly like the paper's modified version)
        "Q2": [q("?p", RDF_TYPE, "Inproceedings"),
               q("?p", "dc:creator", "?author"),
               q("?p", "bench:booktitle", "?bt"),
               q("?p", "dc:title", "?title"),
               q("?p", "dcterms:partOf", "?proc"),
               q("?p", "rdfs:seeAlso", "?ee"),
               q("?p", "swrc:pages", "?pages"),
               q("?p", "foaf:homepage", "?url")],
        # Q3a: articles with a pages property (unselective join)
        "Q3a": [q("?a", RDF_TYPE, "Article"),
                q("?a", "swrc:pages", "?v")],
        # Q10: subject-of — all edges pointing at a person (?s ?p const)
        "Q10": [q("?s", "?pr", "Person0")],
    }
    return triples, d, queries


# SPARQL text forms of the SP²Bench query set (same round-trip contract
# as LUBM_SPARQL; the generator names its prefixes literally — e.g. the
# term "dc:title" — so each prefix maps to its own name + ':')
_SP2B_HDR = """PREFIX rdf: <rdf:>
PREFIX dc: <dc:>
PREFIX dcterms: <dcterms:>
PREFIX bench: <bench:>
PREFIX rdfs: <rdfs:>
PREFIX swrc: <swrc:>
PREFIX foaf: <foaf:>
"""
SP2B_SPARQL = {
    "Q1": _SP2B_HDR + """SELECT ?yr WHERE {
  ?a rdf:type <Article> .
  ?a dc:title "title0" .
  ?a dcterms:issued ?yr .
}""",
    "Q2": _SP2B_HDR + """SELECT * WHERE {
  ?p rdf:type <Inproceedings> .
  ?p dc:creator ?author .
  ?p bench:booktitle ?bt .
  ?p dc:title ?title .
  ?p dcterms:partOf ?proc .
  ?p rdfs:seeAlso ?ee .
  ?p swrc:pages ?pages .
  ?p foaf:homepage ?url .
}""",
    "Q3a": _SP2B_HDR + """SELECT ?a WHERE {
  ?a rdf:type <Article> .
  ?a swrc:pages ?v .
}""",
    "Q10": "SELECT ?s ?pr WHERE { ?s ?pr <Person0> . }",
}
