"""Sharded, elastic, atomic checkpointing.

Format: one directory per step containing `manifest.json` (tree structure,
shapes, dtypes, step) + `arrays.npz` (leaves keyed by '/'-joined path).
Arrays are saved with *global* shapes, so restore is mesh-shape-agnostic:
`load` re-places every leaf with the *target* mesh's NamedSharding — this is
the elastic-scaling path (train on N chips, resume on M chips).

Writes are atomic (tmp dir + rename) and optionally asynchronous (snapshot
to host synchronously, file I/O on a writer thread) so the train loop never
blocks on disk. Fault tolerance = deterministic data keyed by step + these
checkpoints: kill at any point, restart, bit-exact continuation (tested).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np

from repro.common import tree_map_with_path, tree_paths

MANIFEST = "manifest.json"
ARRAYS = "arrays.npz"


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    """npz can't store ml_dtypes (bf16): persist as a uint16 view + marker."""
    out = {}
    for path, leaf in tree_paths(tree):
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":
            out["/".join(path) + "::bf16"] = arr.view(np.uint16)
        else:
            out["/".join(path)] = arr
    return out


def save(workdir: str, step: int, trees: dict[str, Any],
         keep: int = 3) -> str:
    """trees: e.g. {"params": ..., "opt_state": ...}. Returns ckpt path."""
    os.makedirs(workdir, exist_ok=True)
    final = os.path.join(workdir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays: dict[str, np.ndarray] = {}
    spec: dict[str, Any] = {"step": step, "trees": {}}
    for name, tree in trees.items():
        flat = _flatten(tree)
        for k, v in flat.items():
            arrays[f"{name}/{k}"] = v
        spec["trees"][name] = sorted(flat)
    np.savez(os.path.join(tmp, ARRAYS), **arrays)
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(spec, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    _gc(workdir, keep)
    return final


def _gc(workdir: str, keep: int) -> None:
    ckpts = sorted(d for d in os.listdir(workdir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in ckpts[:-keep]:
        shutil.rmtree(os.path.join(workdir, d), ignore_errors=True)


def latest(workdir: str) -> str | None:
    if not os.path.isdir(workdir):
        return None
    ckpts = sorted(d for d in os.listdir(workdir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    return os.path.join(workdir, ckpts[-1]) if ckpts else None


def load(path: str, templates: dict[str, Any],
         shardings: dict[str, Any] | None = None) -> tuple[int, dict[str, Any]]:
    """templates: same-structure trees (arrays or ShapeDtypeStructs).
    shardings: optional same-structure trees of NamedSharding for re-placement
    on a (possibly different) mesh — the elastic-restore path."""
    with open(os.path.join(path, MANIFEST)) as f:
        spec = json.load(f)
    data = np.load(os.path.join(path, ARRAYS))
    out: dict[str, Any] = {}
    for name, template in templates.items():
        def fill(p, leaf):
            key = f"{name}/" + "/".join(p)
            if key + "::bf16" in data:
                import ml_dtypes
                arr = data[key + "::bf16"].view(ml_dtypes.bfloat16)
            else:
                arr = data[key]
            assert tuple(arr.shape) == tuple(leaf.shape), \
                f"{name}/{p}: ckpt {arr.shape} != template {leaf.shape}"
            if shardings is not None:
                return jax.device_put(arr, _lookup(shardings[name], p))
            return jax.device_put(arr.astype(leaf.dtype))
        out[name] = tree_map_with_path(fill, template)
    return spec["step"], out


def _lookup(tree: Any, path: tuple):
    for p in path:
        if isinstance(tree, dict):
            tree = tree[p]
        else:
            tree = tree[int(p)]
    return tree


class AsyncCheckpointer:
    """Snapshot synchronously (device -> host copy), write on a thread."""

    def __init__(self, workdir: str, keep: int = 3):
        self.workdir = workdir
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.last_path: str | None = None

    def save(self, step: int, trees: dict[str, Any]) -> None:
        self.wait()
        host = {name: jax.tree.map(np.asarray, tree)
                for name, tree in trees.items()}

        def _write():
            self.last_path = save(self.workdir, step, host, self.keep)

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
