from repro.checkpoint.checkpoint import AsyncCheckpointer, latest, load, save  # noqa: F401
