"""Pixtral-12B — pixtral-ViT frontend (STUB) + mistral-nemo decoder backbone
[hf:mistralai/Pixtral-12B-2409]. Per assignment, the modality frontend is a
stub: input_specs() provides precomputed patch embeddings."""
from repro.configs.base import ModelConfig, register


@register("pixtral-12b")
def config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b", family="vlm",
        num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
        d_ff=14336, vocab_size=131072, head_dim=128,
        rope_theta=1_000_000_000.0,
        num_patches=256,  # patch embeddings prepended to the text sequence
        embedding_impl="mapsin",
    )
