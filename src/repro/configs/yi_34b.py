"""Yi-34B — dense llama-arch GQA kv=8 [arXiv:2403.04652; hf]."""
from repro.configs.base import ModelConfig, register


@register("yi-34b")
def config() -> ModelConfig:
    return ModelConfig(
        name="yi-34b", family="dense",
        num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8,
        d_ff=20480, vocab_size=64000, head_dim=128,
        rope_theta=5_000_000.0,
    )
