"""DBRX 132B — 16-expert top-4 fine-grained MoE [hf:databricks/dbrx-base]."""
from repro.configs.base import ModelConfig, register


@register("dbrx-132b")
def config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b", family="moe",
        num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8,
        d_ff=10752, vocab_size=100352, head_dim=128,
        num_experts=16, top_k=4, moe_d_ff=10752,
        rope_theta=500_000.0,
        embedding_impl="mapsin",
    )
