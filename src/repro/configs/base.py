"""Config system: model architecture configs, input shapes, registry.

Every assigned architecture gets one ``configs/<arch>.py`` defining a
``CONFIG = ModelConfig(...)`` with the exact published hyper-parameters, and
is selectable via ``--arch <id>`` in every launcher.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | vlm | audio | ssm | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0  # 0 -> d_model // num_heads
    qk_norm: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0  # leading dense layers (deepseek-v3 style)
    dense_d_ff: int = 0          # d_ff for those dense layers (0 -> d_ff)
    router_aux_weight: float = 1e-3
    capacity_factor: float = 1.25

    # --- MLA (deepseek-v3) ---
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    mtp_depth: int = 0  # multi-token-prediction depth

    # --- hybrid (recurrentgemma / griffin) ---
    block_pattern: tuple[str, ...] = ()  # e.g. ("rec", "rec", "attn")
    window_size: int = 0  # sliding window for local attention (0 = full)
    lru_width: int = 0
    conv_width: int = 4

    # --- ssm (xlstm) ---
    slstm_at: tuple[int, ...] = ()
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0

    # --- modality frontend stubs ---
    num_patches: int = 0     # vlm: image patch embeddings prepended to text
    num_codebooks: int = 0   # audio: EnCodec codebooks (frontend stub)

    # --- numerics & implementation switches ---
    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"
    kv_cache_dtype: str = "bfloat16"  # float8_e4m3fn: quantized decode cache
    attention_impl: str = "xla"      # xla (blockwise-flash) | naive | pallas_interpret
    embedding_impl: str = "dense"    # dense | mapsin (distributed_lookup)
    remat_policy: str = "names"      # none | minimal | names | full
    logical_rules: str = "default"   # sharding rule set name (see sharding/rules.py)
    attn_block_q: int = 512          # blockwise attention tile sizes
    attn_block_kv: int = 1024
    causal_split: bool = False       # split-causal flop-saving decomposition
    scan_layers: bool = True         # False: unroll (exact XLA cost analysis)

    # derived ----------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def is_subquadratic(self) -> bool:
        """True if sequence mixing is sub-quadratic (can run long_500k)."""
        return self.family in ("ssm", "hybrid")

    @property
    def moe_layer_ids(self) -> tuple[int, ...]:
        if self.num_experts == 0:
            return ()
        return tuple(range(self.first_dense_layers, self.num_layers))

    def n_params(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, v = self.d_model, self.vocab_size
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        hd = self.resolved_head_dim
        for layer in range(self.num_layers):
            if self.family == "ssm":
                if layer in self.slstm_at:
                    # sLSTM: 4 gates recurrent+input + ffn
                    total += 8 * d * d + int(2 * d * d * self.slstm_proj_factor)
                else:
                    inner = int(d * self.mlstm_proj_factor)
                    total += 2 * d * inner + inner * d + 3 * inner * (inner // max(self.num_heads, 1)) // max(inner // max(self.num_heads, 1), 1)  # approx qkv
                total += 2 * d
                continue
            is_rec = bool(self.block_pattern) and self.block_pattern[layer % len(self.block_pattern)] == "rec" if self.block_pattern else False
            if is_rec:
                w = self.lru_width or d
                total += 2 * d * w + w * d + self.conv_width * w + 2 * w  # rg-lru block
            elif self.use_mla:
                total += d * self.q_lora_rank
                total += self.q_lora_rank * self.num_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim)
                total += d * (self.kv_lora_rank + self.qk_rope_head_dim)
                total += self.kv_lora_rank * self.num_heads * (self.qk_nope_head_dim + self.v_head_dim)
                total += self.num_heads * self.v_head_dim * d
            else:
                total += d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd + self.num_heads * hd * d
            # mlp / moe
            if self.num_experts and layer in self.moe_layer_ids:
                total += (self.num_experts + self.num_shared_experts) * 3 * d * self.moe_d_ff
                total += d * self.num_experts  # router
            else:
                ff = self.dense_d_ff or self.d_ff
                if ff:
                    total += 3 * d * ff
            total += 2 * d  # norms
        return total

    def n_active_params(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if self.num_experts == 0:
            return self.n_params()
        total = self.n_params()
        n_moe = len(self.moe_layer_ids)
        inactive = (self.num_experts - self.top_k) * 3 * self.d_model * self.moe_d_ff * n_moe
        return total - inactive


# ---------------------------------------------------------------------------
# Input shapes (assigned shape set for LM-family transformers)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def runnable_shapes(cfg: ModelConfig) -> list[ShapeConfig]:
    """The shape cells this architecture runs (long_500k needs sub-quadratic)."""
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.is_subquadratic:
        out.append(SHAPES["long_500k"])
    return out


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str, **overrides) -> ModelConfig:
    from repro import configs  # noqa: F401  (triggers arch module imports)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    cfg = _REGISTRY[name]()
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def list_archs() -> list[str]:
    from repro import configs  # noqa: F401
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# Reduced configs for CPU smoke tests
# ---------------------------------------------------------------------------


def reduce_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """Same family/feature set, tiny dims: one forward/train step on CPU."""
    kw: dict = dict(
        num_layers=min(cfg.num_layers, 4),
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 4) or 1,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        head_dim=16,
    )
    if cfg.num_experts:
        kw.update(num_experts=4, top_k=2, moe_d_ff=32,
                  num_shared_experts=min(cfg.num_shared_experts, 1),
                  first_dense_layers=min(cfg.first_dense_layers, 1),
                  dense_d_ff=128 if cfg.dense_d_ff else 0)
    if cfg.use_mla:
        kw.update(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                  qk_rope_head_dim=8, v_head_dim=16, head_dim=0)
    if cfg.mtp_depth:
        kw.update(mtp_depth=1)
    if cfg.block_pattern:
        kw.update(num_layers=5, lru_width=64, window_size=32)  # rec,rec,attn,rec,rec
    if cfg.family == "ssm":
        kw.update(num_layers=4, slstm_at=(3,), d_ff=0)
    if cfg.num_patches:
        kw.update(num_patches=8)
    if cfg.num_codebooks:
        kw.update(num_codebooks=cfg.num_codebooks, vocab_size=64)
    if cfg.window_size and not cfg.block_pattern:
        kw.update(window_size=32)
    kw.update(param_dtype="float32", activation_dtype="float32",
              attn_block_q=16, attn_block_kv=32)
    return dataclasses.replace(cfg, **kw)
