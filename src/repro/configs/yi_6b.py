"""Yi-6B — dense llama-arch with GQA kv=4 [arXiv:2403.04652; hf]."""
from repro.configs.base import ModelConfig, register


@register("yi-6b")
def config() -> ModelConfig:
    return ModelConfig(
        name="yi-6b", family="dense",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=4,
        d_ff=11008, vocab_size=64000, head_dim=128,
        rope_theta=5_000_000.0,
    )
