"""xLSTM-125M — sLSTM + mLSTM blocks [arXiv:2405.04517].
d_ff=0: mixing blocks carry their own projections (mLSTM proj-factor 2 up/down,
sLSTM gated 4/3 FFN). sLSTM placement follows the paper's sparse-ratio style
(~1 sLSTM per 6 blocks)."""
from repro.configs.base import ModelConfig, register


@register("xlstm-125m")
def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m", family="ssm",
        num_layers=12, d_model=768, num_heads=4, num_kv_heads=4,
        d_ff=0, vocab_size=50304,
        slstm_at=(5, 11),
        tie_embeddings=True,
    )
