"""DeepSeek-V3 671B — MLA + 1 shared/256 routed top-8 MoE + MTP
[arXiv:2412.19437; hf]."""
from repro.configs.base import ModelConfig, register


@register("deepseek-v3-671b")
def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b", family="moe",
        num_layers=61, d_model=7168, num_heads=128, num_kv_heads=128,
        d_ff=2048,  # routed-expert hidden dim (assigned shape table value)
        vocab_size=129280,
        # MoE: first 3 layers dense (d_ff 18432), rest 256 routed + 1 shared
        num_experts=256, num_shared_experts=1, top_k=8, moe_d_ff=2048,
        first_dense_layers=3, dense_d_ff=18432,
        # MLA
        use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
        qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
        mtp_depth=1,
        rope_theta=10000.0,
        embedding_impl="mapsin",
    )
