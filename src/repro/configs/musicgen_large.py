"""MusicGen-large — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].
Audio frontend (EnCodec + delay-pattern interleave) is a STUB: input_specs()
provides precomputed frame embeddings; the backbone predicts codebook tokens."""
from repro.configs.base import ModelConfig, register


@register("musicgen-large")
def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large", family="audio",
        num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32,
        d_ff=8192, vocab_size=2048, head_dim=64,
        num_codebooks=4,
        rope_theta=10000.0,
    )
