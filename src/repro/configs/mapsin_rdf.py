"""The paper's own workload config: RDF triple store + MAPSIN join engine.

Not an LM architecture — this config parameterizes the core/ join engine
(store capacity, shard count, probe capacities) for the benchmark harness
and examples. Registered so `--arch mapsin-rdf` selects the paper workload.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class MapsinConfig:
    name: str = "mapsin-rdf"
    num_shards: int = 8           # logical store shards (HBase regions)
    probe_capacity: int = 4       # matches fetched per probe key (per pattern)
    result_capacity: int = 1 << 16  # solution-multiset capacity per shard
    sort_impl: str = "jnp"        # jnp | pallas_interpret
    lookup_impl: str = "jnp"


def config() -> MapsinConfig:
    return MapsinConfig()
