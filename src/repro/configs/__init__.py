"""Architecture registry — importing this package registers all configs."""
from repro.configs.base import (  # noqa: F401
    ModelConfig, ShapeConfig, SHAPES, get_config, list_archs,
    reduce_for_smoke, runnable_shapes,
)

# Assigned architectures (one module per arch) + the paper's own workload.
from repro.configs import (  # noqa: F401
    deepseek_7b, yi_6b, qwen3_8b, yi_34b, deepseek_v3_671b, dbrx_132b,
    pixtral_12b, musicgen_large, xlstm_125m, recurrentgemma_9b, mapsin_rdf,
)
