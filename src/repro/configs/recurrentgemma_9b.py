"""RecurrentGemma-9B — RG-LRU recurrent blocks + local sliding-window
attention in a 2:1 pattern (rec, rec, attn) [arXiv:2402.19427]."""
from repro.configs.base import ModelConfig, register


@register("recurrentgemma-9b")
def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b", family="hybrid",
        num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
        d_ff=12288, vocab_size=256000, head_dim=256,
        block_pattern=("rec", "rec", "attn"),
        window_size=2048, lru_width=4096, conv_width=4,
        rope_theta=10000.0,
        embedding_impl="mapsin",
    )
