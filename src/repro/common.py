"""Shared small utilities used across the framework."""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Iterator

import jax
import jax.numpy as jnp
import numpy as np

# Canonical dtype registry (string names keep configs JSON-serializable).
DTYPES = {
    "float32": jnp.float32,
    "bfloat16": jnp.bfloat16,
    "float16": jnp.float16,
    "int32": jnp.int32,
    "int64": jnp.int64,
    "int8": jnp.int8,
    "float8_e4m3fn": jnp.float8_e4m3fn,
}


def dtype_of(name: str) -> jnp.dtype:
    return DTYPES[name]


def tree_paths(tree: Any, prefix: tuple = ()) -> Iterator[tuple[tuple, Any]]:
    """Yield (path, leaf) for a nested dict/list pytree of leaves."""
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from tree_paths(tree[k], prefix + (k,))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from tree_paths(v, prefix + (str(i),))
    else:
        yield prefix, tree


def tree_map_with_path(fn, tree: Any, prefix: tuple = ()) -> Any:
    if isinstance(tree, dict):
        return {k: tree_map_with_path(fn, v, prefix + (k,)) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        typ = type(tree)
        return typ(tree_map_with_path(fn, v, prefix + (str(i),)) for i, v in enumerate(tree))
    return fn(prefix, tree)


def param_count(tree: Any) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def param_bytes(tree: Any) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize for x in jax.tree.leaves(tree))


def fold_path(key: jax.Array, path: tuple) -> jax.Array:
    """Derive a deterministic per-parameter rng key from a path."""
    h = 0
    for part in path:
        for ch in str(part):
            h = (h * 131 + ord(ch)) % (2**31 - 1)
    return jax.random.fold_in(key, h)


class NpEncoder(json.JSONEncoder):
    def default(self, obj):
        if isinstance(obj, (np.integer,)):
            return int(obj)
        if isinstance(obj, (np.floating,)):
            return float(obj)
        if isinstance(obj, np.ndarray):
            return obj.tolist()
        if dataclasses.is_dataclass(obj):
            return dataclasses.asdict(obj)
        return super().default(obj)


def dump_json(obj: Any, path: str) -> None:
    with open(path, "w") as f:
        json.dump(obj, f, indent=2, cls=NpEncoder)


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return ceil_div(a, b) * b
