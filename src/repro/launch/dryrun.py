import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell we jit the right step function (train_step / prefill_step /
decode_step) with fully sharded abstract inputs (ShapeDtypeStruct — zero
allocation), compile for the 16x16 single-pod and 2x16x16 multi-pod meshes,
and record:
  * memory_analysis()        — bytes/device (proves it fits; §Dry-run)
  * cost_analysis()          — HLO FLOPs + bytes        (roofline terms)
  * collective bytes         — parsed from the post-SPMD HLO text
The per-cell JSON lands in experiments/dryrun/ and feeds §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import re
import time
import traceback

import jax
import numpy as np

from repro.common import dump_json
from repro.configs import SHAPES, get_config, list_archs, runnable_shapes
from repro.launch.mesh import make_production_mesh
from repro.models import (build_model, input_defs, make_decode_step,
                          make_prefill_step, make_train_step)
from repro.models.api import default_micro_batches
from repro.models.params import abstract_tree
from repro.optim import OptConfig, opt_state_defs
from repro.sharding.rules import make_rules

COLLECTIVE_RE = re.compile(
    r"(\w+)\[([\d,]*)\].* (all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum result-shape bytes of every collective op in the (post-SPMD,
    per-device) HLO. Returns bytes per collective kind."""
    out: dict[str, float] = {}
    for m in COLLECTIVE_RE.finditer(hlo_text):
        dt, dims, kind = m.group(1), m.group(2), m.group(3)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out[kind] = out.get(kind, 0) + n * DTYPE_BYTES[dt]
    return out


def build_cell(arch: str, shape_name: str, mesh, opt_cfg=None,
               overrides: dict | None = None,
               rules_overrides: dict | None = None):
    """Returns (jitted fn, abstract args tuple) for one cell."""
    cfg = get_config(arch, **(overrides or {}))
    shape = SHAPES[shape_name]
    rules = make_rules(mesh, cfg, shape, **(rules_overrides or {}))
    model = build_model(cfg, mesh, rules)
    micro = default_micro_batches(cfg, shape, mesh)
    if opt_cfg is None:
        # memory-floor models: bf16 optimizer moments + bf16 grad accumulation
        big = cfg.n_params() > 100e9
        opt_cfg = OptConfig(moment_dtype="bfloat16" if big else "float32")
    bdefs = input_defs(cfg, shape, micro)
    abstract_batch = abstract_tree(bdefs, rules)
    pdefs = model.param_defs()
    abstract_params = abstract_tree(pdefs, rules)
    if shape.kind == "train":
        odefs = opt_state_defs(pdefs, opt_cfg)
        abstract_opt = abstract_tree(odefs, rules)
        import jax.numpy as jnp
        accum = jnp.bfloat16 if opt_cfg.moment_dtype == "bfloat16" else jnp.float32
        fn = make_train_step(model, opt_cfg, micro, accum_dtype=accum)
        return fn, (abstract_params, abstract_opt, abstract_batch), cfg, rules
    if shape.kind == "prefill":
        fn = make_prefill_step(model)
        return fn, (abstract_params, abstract_batch), cfg, rules
    cdefs = model.cache_defs(shape.global_batch, shape.seq_len)
    abstract_cache = abstract_tree(cdefs, rules)
    fn = make_decode_step(model)
    return fn, (abstract_params, abstract_cache, abstract_batch), cfg, rules


def analytic_memory(arch: str, shape_name: str, mesh, overrides=None,
                    rules_overrides=None) -> dict:
    """Exact per-device resident bytes (params/opt/cache/inputs + remat
    stash) from the sharded ParamDef trees — the TPU 'fits' criterion.
    (The CPU backend's temp_size includes f32 copies of every bf16 weight,
    an artifact that does not exist on TPU where the MXU eats bf16.)"""
    from repro.models.params import sharded_bytes_per_device
    cfg = get_config(arch, **(overrides or {}))
    shape = SHAPES[shape_name]
    rules = make_rules(mesh, cfg, shape, **(rules_overrides or {}))
    model = build_model(cfg, mesh, rules)
    micro = default_micro_batches(cfg, shape, mesh)
    out = {"micro_batches": micro}
    pdefs = model.param_defs()
    out["params"] = sharded_bytes_per_device(pdefs, rules)
    if shape.kind == "train":
        big = cfg.n_params() > 100e9
        ocfg = OptConfig(moment_dtype="bfloat16" if big else "float32")
        out["opt"] = sharded_bytes_per_device(opt_state_defs(pdefs, ocfg), rules)
        out["grad_accum"] = out["params"] * (1 if big else 2) if micro > 1 else 0
        dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
        rows_local = max(shape.global_batch // micro // dp, 1)
        out["remat_stash"] = (cfg.num_layers * rows_local * shape.seq_len
                              * cfg.d_model * 2)
    if shape.kind == "decode":
        cdefs = model.cache_defs(shape.global_batch, shape.seq_len)
        out["cache"] = sharded_bytes_per_device(cdefs, rules)
    out["batch"] = sharded_bytes_per_device(input_defs(cfg, shape, micro), rules)
    out["total"] = sum(v for k, v in out.items() if k != "micro_batches")
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = "experiments/dryrun", verbose: bool = True,
             overrides: dict | None = None, tag: str = "",
             rules_overrides: dict | None = None) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    fn, args, cfg, rules = build_cell(arch, shape_name, mesh,
                                      overrides=overrides,
                                      rules_overrides=rules_overrides)
    with mesh:
        lowered = jax.jit(fn).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = {}
        try:
            ma = compiled.memory_analysis()
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
                if hasattr(ma, k):
                    mem[k] = int(getattr(ma, k))
        except Exception as e:  # backend without memory analysis
            mem["error"] = str(e)
        cost = compiled.cost_analysis() or {}
        cost = {k: float(v) for k, v in cost.items()
                if isinstance(v, (int, float)) and k in
                ("flops", "bytes accessed", "transcendentals",
                 "utilization operand 0 {}", "bytes accessed output {}")}
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
    n_chips = int(np.prod(list(mesh.shape.values())))
    report = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "tag": tag, "chips": n_chips,
        "kind": SHAPES[shape_name].kind,
        "n_params": cfg.n_params(), "n_active_params": cfg.n_active_params(),
        "seq_len": SHAPES[shape_name].seq_len,
        "global_batch": SHAPES[shape_name].global_batch,
        "memory_analysis": mem,
        "analytic_memory": analytic_memory(arch, shape_name, mesh, overrides, rules_overrides),
        "cost_analysis": cost,
        "collective_bytes": coll,
        "hlo_bytes": len(hlo),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "kv_mode": rules.kv_mode,
    }
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"_{tag}" if tag else ""
    path = os.path.join(out_dir, f"{arch}_{shape_name}_{mesh_name}{suffix}.json")
    dump_json(report, path)
    if verbose:
        per_dev = mem.get("temp_size_in_bytes", 0) + mem.get("argument_size_in_bytes", 0)
        ana = report["analytic_memory"]["total"]
        print(f"[dryrun] {arch:20s} {shape_name:12s} {mesh_name:10s} "
              f"flops={cost.get('flops', 0):.3e} coll={sum(coll.values()):.3e}B "
              f"mem/dev={per_dev/2**30:.2f}GiB resid/dev={ana/2**30:.2f}GiB "
              f"compile={t_compile:.0f}s")
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    cells: list[tuple[str, str]] = []
    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    for arch in archs:
        cfg = get_config(arch)
        shapes = ([SHAPES[args.shape]] if args.shape
                  else runnable_shapes(cfg))
        for sh in shapes:
            cells.append((arch, sh.name))
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    failures = []
    for arch, shape in cells:
        for mp in meshes:
            try:
                run_cell(arch, shape, mp, args.out)
            except Exception as e:
                failures.append((arch, shape, mp, str(e)))
                print(f"[dryrun] FAIL {arch} {shape} multi_pod={mp}: {e}")
                traceback.print_exc(limit=3)
    print(f"[dryrun] done: {len(cells) * len(meshes) - len(failures)} ok, "
          f"{len(failures)} failed")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
