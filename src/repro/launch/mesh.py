"""Production mesh construction (a function, never module-level state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = one v5e pod (256 chips); 2x16x16 = two pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_for(n_devices: int, model_par: int = 1):
    """Small helper for tests/examples on however many devices exist."""
    assert n_devices % model_par == 0
    if model_par > 1:
        return jax.make_mesh((n_devices // model_par, model_par),
                             ("data", "model"))
    return jax.make_mesh((n_devices,), ("data",))
