"""Analytic roofline cost model — FLOPs / HBM bytes / collective bytes.

Why analytic: XLA's `compiled.cost_analysis()` counts every while-loop body
ONCE, so scan-over-layers, microbatch accumulation, blockwise attention and
chunked losses are all undercounted by their trip counts (verified in
tests/test_costmodel.py, where the model is calibrated against XLA on
shallow UNROLLED configs — agreement within a few % on flops). The formulas
below mirror the implementation op-for-op, including its inefficiencies
(full-rectangle causal blocks, MoE capacity padding, remat recompute), which
is exactly what §Perf hillclimbs.

Terms follow the assignment:
    compute    = FLOPs_global   / (chips * 197e12)      [bf16 peak / chip]
    memory     = HBM_global     / (chips * 819e9)       [HBM bw / chip]
    collective = coll_global    / (chips * 50e9)        [ICI link bw / chip]
"""
from __future__ import annotations

import dataclasses
from typing import Any

from repro.configs.base import ModelConfig, ShapeConfig

PEAK_FLOPS = 197e12   # TPU v5e bf16 / chip
HBM_BW = 819e9        # bytes/s / chip
ICI_BW = 50e9         # bytes/s / link / chip


@dataclasses.dataclass
class CellCost:
    flops: float          # global, per step
    model_flops: float    # 6*N_active*D (train) / 2*N_active*D (serve)
    hbm_bytes: float      # global, per step
    coll_bytes: float     # global, per step
    detail: dict[str, float]

    def terms(self, chips: int) -> dict[str, Any]:
        compute = self.flops / (chips * PEAK_FLOPS)
        memory = self.hbm_bytes / (chips * HBM_BW)
        coll = self.coll_bytes / (chips * ICI_BW)
        dom = max(("compute", compute), ("memory", memory),
                  ("collective", coll), key=lambda t: t[1])
        step = max(compute, memory, coll)
        return {
            "compute_s": compute, "memory_s": memory, "collective_s": coll,
            "dominant": dom[0],
            "useful_ratio": self.model_flops / max(self.flops, 1),
            "roofline_fraction": (self.model_flops / (chips * PEAK_FLOPS)) / max(step, 1e-30),
            "step_s": step,
        }


def _attn_flops_per_token(cfg: ModelConfig, kv_span: float, causal_factor: float) -> float:
    """scores + pv flops per token for one layer (fwd)."""
    h = cfg.num_heads
    if cfg.use_mla:
        eq = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
        ev = cfg.v_head_dim
    else:
        eq = ev = cfg.resolved_head_dim
    return 2.0 * h * kv_span * (eq + ev) * causal_factor


def _layer_fwd_flops_per_token(cfg: ModelConfig, layer: int, seq: int,
                               block_q: int, triangle: bool) -> float:
    """One layer's forward matmul flops per token (projections + mixing + FFN)."""
    d = cfg.d_model
    h, g, e = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    f = 0.0
    is_rec = bool(cfg.block_pattern) and \
        cfg.block_pattern[layer % len(cfg.block_pattern)] == "rec"
    if cfg.family == "ssm":
        inner = int(d * cfg.mlstm_proj_factor)
        if layer in cfg.slstm_at:
            f += 2 * d * 4 * d + 4 * 2 * (d // max(cfg.num_heads, 1)) * d  # W + R
            f += 3 * 2 * d * int(d * cfg.slstm_proj_factor)                # ffn
        else:
            em = inner // cfg.num_heads
            f += 2 * d * 2 * inner + 3 * 2 * inner * inner + 2 * inner * d
            # chunkwise mixing: intra (2*L_chunk) + inter/state (4*em)
            from repro.models.xlstm import CHUNK
            f += 2 * cfg.num_heads * em * (2 * CHUNK + 4 * em)
        return f
    if is_rec:
        w = cfg.lru_width
        f += 2 * d * w * 2 + 2 * w * w * 2 + 2 * w * d + 2 * cfg.conv_width * w
        f += 10 * w  # scan combine work
    elif cfg.use_mla:
        dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
        f += 2 * d * cfg.q_lora_rank + 2 * cfg.q_lora_rank * h * (dn + dr)
        f += 2 * d * (cfg.kv_lora_rank + dr)
        f += 2 * cfg.kv_lora_rank * h * (dn + dv)
        f += 2 * h * dv * d
        span = seq  # full-rectangle blockwise baseline
        f += _attn_flops_per_token(cfg, span, 0.5 if False else 1.0)
    else:
        f += 2 * d * h * e + 2 * 2 * d * g * e + 2 * h * e * d
        if cfg.window_size:
            span = min(cfg.window_size + block_q, seq)
            f += _attn_flops_per_token(cfg, span, 1.0)
        else:
            span = seq
            factor = 0.5 + 0.5 / max(seq // block_q, 1) if triangle else 1.0
            f += _attn_flops_per_token(cfg, span, factor)
    # FFN
    if cfg.num_experts and layer >= cfg.first_dense_layers:
        f += 2 * d * cfg.num_experts  # router
        f += cfg.top_k * cfg.capacity_factor * 3 * 2 * d * cfg.moe_d_ff
        f += cfg.num_shared_experts * 3 * 2 * d * cfg.moe_d_ff
    else:
        ff = (cfg.dense_d_ff or cfg.d_ff)
        if ff:
            f += 3 * 2 * d * ff
    return f


def _fwd_flops_per_token(cfg: ModelConfig, seq: int) -> float:
    total = 0.0
    tri = cfg.attention_impl == "xla_tri"
    for layer in range(cfg.num_layers):
        total += _layer_fwd_flops_per_token(cfg, layer, seq, cfg.attn_block_q, tri)
    total += 2 * cfg.d_model * cfg.vocab_size * (cfg.num_codebooks or 1)  # head
    if cfg.mtp_depth:
        total += _layer_fwd_flops_per_token(cfg, cfg.num_layers - 1, seq,
                                            cfg.attn_block_q, tri)
        total += 2 * (2 * cfg.d_model) * cfg.d_model
        total += 2 * cfg.d_model * cfg.vocab_size
    return total


def _param_bytes(cfg: ModelConfig) -> float:
    return cfg.n_params() * 2.0  # bf16


def _mesh_dims(mesh_shape: dict[str, int]):
    dp = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    tp = mesh_shape.get("model", 1)
    return dp, tp


def _expert_param_bytes(cfg: ModelConfig) -> float:
    """Bytes of routed-expert weights (bf16) — EP keeps them in place."""
    if not cfg.num_experts:
        return 0.0
    n_moe = len(cfg.moe_layer_ids)
    return cfg.num_experts * 3 * cfg.d_model * cfg.moe_d_ff * n_moe * 2.0


def cost_train(cfg: ModelConfig, shape: ShapeConfig, mesh_shape: dict[str, int],
               micro_batches: int = 1, assume_ep: bool | None = None) -> CellCost:
    tokens = shape.global_batch * shape.seq_len
    dp, tp = _mesh_dims(mesh_shape)
    chips = dp * tp
    fwd = _fwd_flops_per_token(cfg, shape.seq_len) * tokens
    # bwd = 2x fwd; full remat re-runs fwd once more
    remat_extra = {"none": 0.0, "minimal": 0.5, "names": 1.0, "full": 1.0}[cfg.remat_policy]
    flops = fwd * (3.0 + remat_extra)
    model_flops = 6.0 * cfg.n_active_params() * tokens
    # --- HBM ---
    pbytes = _param_bytes(cfg)
    big = cfg.n_params() > 100e9
    mom_b = 2.0 if big else 4.0  # bf16 moments for memory-floor models
    opt_bytes = cfg.n_params() * 2 * mom_b
    act_stash = cfg.num_layers * tokens / micro_batches * cfg.d_model * 2.0
    hbm = (
        pbytes * (2.0 + remat_extra) * micro_batches   # weights streamed fwd+bwd(+remat) per microbatch
        + pbytes + opt_bytes * 2 + cfg.n_params() * mom_b  # optimizer r/w + grads
        + act_stash * 2.0 * micro_batches               # stash write+read per microbatch
        + tokens * cfg.d_model * 2.0 * 8.0              # transient activation streams
    )
    # --- collectives: TOTAL link-crossing bytes, ring accounting ---
    #   all-gather / reduce-scatter of global tensor T over n: T*(n-1)
    #   all-reduce: 2*T*(n-1);  all-to-all: ~T
    coll = 0.0
    ep_wide = bool(cfg.num_experts) and cfg.num_experts % chips == 0
    if assume_ep is not None:
        ep_wide = assume_ep
    expert_b = _expert_param_bytes(cfg) if ep_wide else 0.0
    fsdp_b = max(pbytes - expert_b, 0.0)   # EP weights never gather
    passes = 2.0 + remat_extra
    if dp > 1:
        # FSDP weight all-gathers (fwd + bwd + remat) per microbatch
        coll += fsdp_b * passes * micro_batches * (dp - 1)
        # gradient reduce-scatter per microbatch (non-expert grads)
        grad_b = (cfg.n_params() * 2.0 - expert_b) * (1.0 if big else 2.0)
        coll += max(grad_b, 0.0) * micro_batches * (dp - 1)
    if tp > 1:
        # 3 per-layer TP combines (attn-out AR, mlp-down AR, carry AG/RS),
        # each ~an all-reduce of the global (tokens x d) bf16 activation
        t_act = tokens * cfg.d_model * 2.0
        coll += 3.0 * cfg.num_layers * 2.0 * t_act * (tp - 1) * passes / 2.0
    if ep_wide:
        # MoE dispatch + combine a2a of routed activations per pass
        t_routed = (tokens * cfg.top_k * cfg.capacity_factor
                    * cfg.d_model * 2.0)
        coll += 2.0 * len(cfg.moe_layer_ids) * t_routed * passes
    if cfg.embedding_impl == "mapsin" and tp > 1:
        coll += 2.0 * 2.0 * tokens * cfg.d_model * 2.0 * (tp - 1)  # psum rows
    detail = {"fwd_flops": fwd, "param_bytes": pbytes, "act_stash": act_stash,
              "fsdp_gather_bytes": fsdp_b}
    return CellCost(flops, model_flops, hbm, coll, detail)


def cost_serve(cfg: ModelConfig, shape: ShapeConfig, mesh_shape: dict[str, int],
               prefill: bool, wide_mlp: bool = False) -> CellCost:
    """Serving: weights are TP-sharded and replicated over `dp` (no FSDP),
    except wide-EP expert weights (sharded over all chips, streamed once)."""
    dp, tp = _mesh_dims(mesh_shape)
    chips = dp * tp
    ep_wide = bool(cfg.num_experts) and cfg.num_experts % chips == 0
    expert_b = _expert_param_bytes(cfg) if ep_wide else 0.0
    dense_b = _param_bytes(cfg) - expert_b
    # every dp replica streams its TP slice of the dense weights per step
    mlp_b = 3 * cfg.d_model * (cfg.dense_d_ff or cfg.d_ff) * cfg.num_layers * 2.0 \
        if cfg.d_ff else 0.0
    if wide_mlp:
        # §Perf iteration C: d_ff sharded over data x model — the MLP weights
        # stream ONCE globally instead of once per data replica
        weight_stream = (dense_b - mlp_b) * dp + mlp_b + expert_b
    else:
        weight_stream = dense_b * dp + expert_b
    if prefill:
        tokens = shape.global_batch * shape.seq_len
        flops = _fwd_flops_per_token(cfg, shape.seq_len) * tokens
        model_flops = 2.0 * cfg.n_active_params() * tokens
        hbm = (weight_stream + tokens * cfg.d_model * 2.0 * 8.0
               + _cache_bytes(cfg, shape))
        coll = 0.0
        if tp > 1:
            t_act = tokens * cfg.d_model * 2.0
            coll += 2.0 * cfg.num_layers * 2.0 * t_act * (tp - 1)
        if ep_wide:
            coll += 2.0 * len(cfg.moe_layer_ids) * tokens * cfg.top_k \
                * cfg.capacity_factor * cfg.d_model * 2.0
        return CellCost(flops, model_flops, hbm, coll, {})
    # decode: one token per sequence
    tokens = shape.global_batch
    flops = _fwd_flops_per_token_decode(cfg, shape.seq_len) * tokens
    model_flops = 2.0 * cfg.n_active_params() * tokens
    hbm = weight_stream + _cache_bytes(cfg, shape)
    coll = 0.0
    if tp > 1:
        t_act = tokens * cfg.d_model * 2.0
        coll += 2.0 * cfg.num_layers * 2.0 * t_act * (tp - 1)
    if ep_wide:
        coll += 2.0 * len(cfg.moe_layer_ids) * tokens * cfg.top_k \
            * cfg.d_model * 2.0
    if cfg.embedding_impl == "mapsin" and tp > 1:
        coll += 2.0 * tokens * cfg.d_model * 2.0 * (tp - 1)
    return CellCost(flops, model_flops, hbm, coll, {})


def _cache_bytes(cfg: ModelConfig, shape: ShapeConfig) -> float:
    from repro.common import dtype_of
    import numpy as np
    kvb = np.dtype(dtype_of(cfg.kv_cache_dtype)).itemsize
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "ssm":
        inner = int(cfg.d_model * cfg.mlstm_proj_factor)
        em = inner // cfg.num_heads
        per = cfg.num_heads * (em * em + em + 1) * 4.0
        return cfg.num_layers * b * per
    if cfg.family == "hybrid":
        n_attn = sum(1 for i in range(cfg.num_layers)
                     if cfg.block_pattern[i % len(cfg.block_pattern)] == "attn")
        n_rec = cfg.num_layers - n_attn
        w = min(cfg.window_size, s)
        return (n_attn * b * w * cfg.num_kv_heads * cfg.resolved_head_dim * 2 * 2.0
                + n_rec * b * cfg.lru_width * (4.0 + 2.0 * (cfg.conv_width - 1)))
    if cfg.use_mla:
        return cfg.num_layers * b * s * (cfg.kv_lora_rank + cfg.qk_rope_head_dim) * kvb
    return cfg.num_layers * b * s * cfg.num_kv_heads * cfg.resolved_head_dim * 2 * kvb


def _fwd_flops_per_token_decode(cfg: ModelConfig, cache_len: int) -> float:
    """Decode reads the cache instead of seq-wide attention."""
    total = 0.0
    for layer in range(cfg.num_layers):
        if cfg.family == "ssm" or (cfg.block_pattern and
                                   cfg.block_pattern[layer % len(cfg.block_pattern)] == "rec"):
            total += _layer_fwd_flops_per_token(cfg, layer, 1, cfg.attn_block_q, False)
            continue
        span = min(cfg.window_size, cache_len) if cfg.window_size else cache_len
        d, h, g, e = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
        if cfg.use_mla:
            c = cfg.kv_lora_rank
            dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
            f = 2 * d * cfg.q_lora_rank + 2 * cfg.q_lora_rank * h * (dn + dr)
            f += 2 * d * (c + dr) + 2 * h * dn * c + 2 * h * dv * c  # absorbed
            f += 2 * h * span * (c + dr) + 2 * h * span * c          # latent attn
            f += 2 * h * dv * d
        else:
            f = 2 * d * h * e + 4 * d * g * e + 2 * h * e * d
            f += 2 * h * e * span * 2
        if cfg.num_experts and layer >= cfg.first_dense_layers:
            f += 2 * d * cfg.num_experts
            f += cfg.top_k * 3 * 2 * d * cfg.moe_d_ff
            f += cfg.num_shared_experts * 3 * 2 * d * cfg.moe_d_ff
        else:
            ff = (cfg.dense_d_ff or cfg.d_ff)
            if ff:
                f += 3 * 2 * d * ff
        total += f
    total += 2 * cfg.d_model * cfg.vocab_size * (cfg.num_codebooks or 1)
    return total


def cost_cell(cfg: ModelConfig, shape: ShapeConfig, mesh_shape: dict[str, int],
              micro_batches: int = 1, **kw) -> CellCost:
    if shape.kind == "train":
        return cost_train(cfg, shape, mesh_shape, micro_batches, **kw)
    return cost_serve(cfg, shape, mesh_shape,
                      prefill=(shape.kind == "prefill"), **kw)
