"""Serving launcher: prefill a prompt batch, decode N tokens.

``python -m repro.launch.serve --arch yi-6b --smoke --tokens 16``
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_for_smoke
from repro.models import build_model, make_decode_step, make_prefill_step
from repro.models.params import init_tree


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    model = build_model(cfg)
    params = init_tree(model.param_defs(), jax.random.key(0))
    rng = np.random.RandomState(0)
    if cfg.family == "audio":
        toks = rng.randint(0, cfg.vocab_size,
                           (args.batch, args.prompt_len, cfg.num_codebooks))
    else:
        toks = rng.randint(0, cfg.vocab_size, (args.batch, args.prompt_len))
    batch = {"tokens": jnp.asarray(toks, jnp.int32)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.randn(args.batch, cfg.num_patches, 1024), jnp.float32)

    prefill = jax.jit(make_prefill_step(model))
    decode = jax.jit(make_decode_step(model))
    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    out = []
    t0 = time.perf_counter()
    for _ in range(args.tokens):
        nxt = jnp.argmax(logits, axis=-1)
        if cfg.family == "audio":
            tok = nxt[:, None, :].astype(jnp.int32)
        else:
            tok = nxt[:, None].astype(jnp.int32)
        logits, cache = decode(params, cache, {"tokens": tok})
        out.append(np.asarray(nxt))
    jax.block_until_ready(logits)
    t_decode = (time.perf_counter() - t0) / args.tokens
    print(f"prefill({args.prompt_len} tok x {args.batch}): {t_prefill*1e3:.1f} ms")
    print(f"decode: {t_decode*1e3:.2f} ms/token")
    print("sampled ids:", np.stack(out, 1)[0].ravel()[:16])


if __name__ == "__main__":
    main()
