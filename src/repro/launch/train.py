"""Training launcher: ``python -m repro.launch.train --arch <id> [--smoke]``.

Runs the fault-tolerant Trainer (checkpoint/restart, straggler watchdog) on
whatever devices exist. --smoke uses the reduced config (CPU-friendly);
without it, the full config is instantiated (requires a real cluster).
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, reduce_for_smoke
from repro.configs.base import SHAPES, ShapeConfig
from repro.optim import OptConfig
from repro.runtime import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--workdir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=20)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
        shape = ShapeConfig("cli", args.seq, args.batch, "train")
    else:
        shape = SHAPES["train_4k"]
    trainer = Trainer(cfg, shape, args.workdir, OptConfig(warmup_steps=10),
                      ckpt_every=args.ckpt_every)

    def hook(step, metrics):
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:5d}  loss {float(metrics['loss']):.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"lr {float(metrics['lr']):.2e}")

    trainer.run(args.steps, hook=hook)
    print(f"done; stragglers flagged: {trainer.watchdog.events}")


if __name__ == "__main__":
    main()
