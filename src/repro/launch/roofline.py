"""Roofline report: merge dry-run artifacts with the analytic cost model.

For every (arch x shape x mesh) JSON under experiments/dryrun/ emit the three
terms (compute / memory / collective, in seconds), the dominant bottleneck,
MODEL_FLOPS/HLO_FLOPs, and the per-device residency — as a markdown table
(EXPERIMENTS.md §Roofline) and a machine-readable JSON.

Usage: PYTHONPATH=src python -m repro.launch.roofline [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import SHAPES, get_config
from repro.launch.costmodel import cost_cell

MESH_SHAPES = {"pod16x16": {"data": 16, "model": 16},
               "pod2x16x16": {"pod": 2, "data": 16, "model": 16}}


def analyze(path: str) -> dict:
    r = json.load(open(path))
    if r.get("tag"):
        return None  # perf-iteration artifacts are reported in §Perf
    cfg = get_config(r["arch"])
    shape = SHAPES[r["shape"]]
    mesh_shape = MESH_SHAPES[r["mesh"]]
    micro = r.get("analytic_memory", {}).get("micro_batches", 1)
    # EP rules always fully shard expert weights (over data and/or model)
    kw = {"assume_ep": True} if (cfg.num_experts and shape.kind == "train") else {}
    cost = cost_cell(cfg, shape, mesh_shape, micro, **kw)
    terms = cost.terms(r["chips"])
    resid = r.get("analytic_memory", {}).get("total", 0)
    return {
        "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
        "chips": r["chips"],
        **{k: terms[k] for k in ("compute_s", "memory_s", "collective_s",
                                 "dominant", "useful_ratio",
                                 "roofline_fraction")},
        "model_flops": cost.model_flops,
        "analytic_flops": cost.flops,
        "hlo_flops_raw": r["cost_analysis"].get("flops", 0),
        "hlo_collective_bytes_raw": sum(r["collective_bytes"].values()),
        "analytic_coll_bytes": cost.coll_bytes,
        "resident_gib": resid / 2**30,
        "fits_16g": resid < 16 * 2**30,
        "compile_s": r.get("compile_s"),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--out", default="experiments/roofline.json")
    ap.add_argument("--mesh", default="pod16x16",
                    help="mesh for the markdown table (the single-pod "
                         "roofline per assignment)")
    args = ap.parse_args()
    rows = []
    for path in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        row = analyze(path)
        if row:
            rows.append(row)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=2)
    # markdown table (single-pod per assignment)
    hdr = ("| arch | shape | compute_s | memory_s | collective_s | dominant "
           "| useful | roofline_frac | resid GiB | fits |")
    print(hdr)
    print("|" + "---|" * 10)
    for r in rows:
        if r["mesh"] != args.mesh:
            continue
        print(f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
              f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
              f"{r['dominant']} | {r['useful_ratio']:.2f} | "
              f"{r['roofline_fraction']:.2f} | {r['resident_gib']:.2f} | "
              f"{'Y' if r['fits_16g'] else 'N'} |")


if __name__ == "__main__":
    main()
