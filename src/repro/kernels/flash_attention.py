"""Pallas TPU kernel: blocked causal flash attention (forward).

Grid (batch*heads, q_blocks, kv_blocks); online-softmax statistics live in
VMEM scratch across the kv dimension (the innermost, sequential grid dim).
Causality skips fully-masked kv blocks via `pl.when` — unlike the XLA
blockwise baseline, masked blocks cost zero MXU work here (the roofline
§Perf 'attention waste' story on real hardware).

GQA is handled by the kv BlockSpec index map (query head h reads kv head
h // rep) — kv is never materialized per query head.

VMEM per step (Bq=512, Bkv=512, e=128, bf16): q/k/v tiles ~0.4 MB + fp32
acc (Bq x e) 0.25 MB + (Bq x Bkv) logits tile 1 MB — well under budget,
MXU-aligned (multiples of 128 on every contraction dim).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, block_q: int, block_kv: int,
            nkv: int, kv_len: int):
    i, j = pl.program_id(1), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    def _compute():
        q = q_ref[0].astype(jnp.float32)       # (Bq, e)
        k = k_ref[0].astype(jnp.float32)       # (Bkv, e)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = i * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = j * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = k_pos < kv_len
        if causal:
            mask = mask & (k_pos <= q_pos)
        s = jnp.where(mask, s, NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None]) * mask
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
        m_ref[...] = m_new

    if causal:
        # skip kv blocks strictly above the diagonal: zero MXU work there
        pl.when(j * block_kv <= i * block_q + block_q - 1)(_compute)
    else:
        _compute()

    @pl.when(j == nkv - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, scale: float | None = None,
                    block_q: int = 512, block_kv: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q: (b, sq, h, e); k/v: (b, skv, g, e) with h % g == 0."""
    b, sq, h, e = q.shape
    skv, g = k.shape[1], k.shape[2]
    rep = h // g
    scale = scale or e ** -0.5
    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    pad_q = (-sq) % block_q
    pad_kv = (-skv) % block_kv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    # (b, s, h, e) -> (b*h, s, e); kv stays (b*g, s, e), indexed via the map
    qr = q.transpose(0, 2, 1, 3).reshape(b * h, q.shape[1], e)
    kr = k.transpose(0, 2, 1, 3).reshape(b * g, k.shape[1], e)
    vr = v.transpose(0, 2, 1, 3).reshape(b * g, v.shape[1], e)
    nq = q.shape[1] // block_q
    nkv = k.shape[1] // block_kv

    def kv_index(bh, i, j):
        return ((bh // h) * g + (bh % h) // rep, j, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal,
                          block_q=block_q, block_kv=block_kv, nkv=nkv,
                          kv_len=skv),
        grid=(b * h, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, block_q, e), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, block_kv, e), kv_index),
            pl.BlockSpec((1, block_kv, e), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, e), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, q.shape[1], e), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, e), jnp.float32),   # acc
            pltpu.VMEM((block_q,), jnp.float32),     # m
            pltpu.VMEM((block_q,), jnp.float32),     # l
        ],
        interpret=interpret,
    )(qr, kr, vr)
    out = out.reshape(b, h, q.shape[1], e).transpose(0, 2, 1, 3)
    return out[:, :sq]
