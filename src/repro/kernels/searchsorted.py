"""Pallas TPU kernel: blocked lexicographic searchsorted — the index GET.

The MAPSIN hot-spot is rank-finding probes against the sorted composite-key
index (HBase GET -> binary search). A GPU port would do per-thread binary
search (divergent, gather-heavy); the TPU-native rethink (DESIGN.md §2):

  * keys live as THREE int32 columns (s, p, o in index order) — TPU has no
    native int64 vectors, and lexicographic compare on 3 x int32 is pure VPU.
  * rank(q) = #{keys < q}, accumulated key-block by key-block over the grid;
    inside a (Bq x Bk) tile the compare matrix is one vectorized op.
  * sortedness is exploited with scalar block bounds + `pl.when`: a key block
    entirely below every query in the tile contributes its size without any
    elementwise work; entirely above contributes zero — the grid walks the
    index like a B-tree, element compares only at boundary blocks.

VMEM per step: Bk*3 + Bq*3 int32 + (Bq x Bk) compare tile. Defaults
(Bq=256, Bk=2048) ≈ 2.2 MB — comfortably inside the ~16 MB VMEM budget,
and Bk=2048 int32 rows are (16, 128)-lane aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _less3(a0, a1, a2, b0, b1, b2):
    """Lexicographic (a0,a1,a2) < (b0,b1,b2), elementwise."""
    return (a0 < b0) | ((a0 == b0) & ((a1 < b1) | ((a1 == b1) & (a2 < b2))))


def _kernel(k_ref, q_ref, out_ref, *, block_k: int, nk: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ks0, ks1, ks2 = k_ref[:, 0], k_ref[:, 1], k_ref[:, 2]
    qs0, qs1, qs2 = q_ref[:, 0], q_ref[:, 1], q_ref[:, 2]

    # scalar block bounds (keys sorted; padding rows are +INF sentinels)
    kmax = (ks0[-1], ks1[-1], ks2[-1])
    kmin = (ks0[0], ks1[0], ks2[0])
    qmin0 = jnp.min(qs0)
    # conservative scalar tests: whole key block strictly below ALL queries?
    blk_below = _less3(kmax[0], kmax[1], kmax[2],
                       jnp.min(qs0), jnp.min(qs1) * 0 - (1 << 30),
                       jnp.min(qs2) * 0 - (1 << 30))
    # whole key block >= ALL queries? (kmin >= max query)
    blk_above = ~_less3(kmin[0], kmin[1], kmin[2],
                        jnp.max(qs0), jnp.max(qs1) * 0 + (1 << 30),
                        jnp.max(qs2) * 0 + (1 << 30))

    @pl.when(blk_below)
    def _all():  # every key in block < every query: add block size
        out_ref[...] = out_ref[...] + block_k

    @pl.when(jnp.logical_not(blk_below) & jnp.logical_not(blk_above))
    def _boundary():  # elementwise compare tile
        lt = _less3(ks0[:, None], ks1[:, None], ks2[:, None],
                    qs0[None, :], qs1[None, :], qs2[None, :])
        # keep the accumulator int32: jnp.sum would promote under x64
        out_ref[...] = out_ref[...] + jnp.sum(lt.astype(jnp.int32), axis=0,
                                              dtype=jnp.int32)


def searchsorted3(keys3: jax.Array, queries3: jax.Array, *,
                  block_k: int = 2048, block_q: int = 256,
                  interpret: bool = False) -> jax.Array:
    """keys3: (M, 3) int32 lexicographically sorted (pad with INT32_MAX rows);
    queries3: (Q, 3) int32. Returns ranks (Q,) int32 ('left' semantics)."""
    m, q = keys3.shape[0], queries3.shape[0]
    pad_k = (-m) % block_k
    pad_q = (-q) % block_q
    if pad_k:
        keys3 = jnp.pad(keys3, ((0, pad_k), (0, 0)),
                        constant_values=jnp.iinfo(jnp.int32).max)
    if pad_q:
        queries3 = jnp.pad(queries3, ((0, pad_q), (0, 0)),
                           constant_values=jnp.iinfo(jnp.int32).max)
    nk = keys3.shape[0] // block_k
    nq = queries3.shape[0] // block_q
    out = pl.pallas_call(
        functools.partial(_kernel, block_k=block_k, nk=nk),
        grid=(nq, nk),
        in_specs=[
            pl.BlockSpec((block_k, 3), lambda i, j: (j, 0)),
            pl.BlockSpec((block_q, 3), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_q,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((queries3.shape[0],), jnp.int32),
        interpret=interpret,
    )(keys3, queries3)
    return out[:q]
