"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def searchsorted_ref(keys: jax.Array, queries: jax.Array) -> jax.Array:
    """keys: (M,) sorted int64; queries: (Q,) int64 -> 'left' ranks."""
    return jnp.searchsorted(keys, queries).astype(jnp.int32)


def searchsorted3_ref(keys3: jax.Array, queries3: jax.Array) -> jax.Array:
    """Lexicographic 3-column searchsorted via packed int64 compare."""
    def pack(c):
        c = c.astype(jnp.int64)
        return (c[:, 0] << 42) | (c[:, 1] << 21) | c[:, 2]
    return jnp.searchsorted(pack(keys3), pack(queries3)).astype(jnp.int32)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, scale: float | None = None) -> jax.Array:
    """Full-score reference attention. q: (b,sq,h,e), k/v: (b,skv,g,e)."""
    b, sq, h, e = q.shape
    skv, g = k.shape[1], k.shape[2]
    scale = scale or e ** -0.5
    qg = q.reshape(b, sq, g, h // g, e)
    s = jnp.einsum("bqgre,bkge->bgrqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrqk,bkge->bqgre", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, e).astype(q.dtype)
