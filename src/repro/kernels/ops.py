"""jit'd public wrappers around the Pallas kernels.

On this CPU container kernels run with interpret=True (Mosaic custom calls
do not lower on the CPU backend); on TPU the same entry points compile
natively. The jnp fallbacks in models/ and core/ are numerically identical
(validated in tests/test_kernels_*.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.rdf import BITS, MAX_ID
from repro.kernels import flash_attention as _fa
from repro.kernels import probe_gather as _pg
from repro.kernels import searchsorted as _ss


def unpack_to_cols(keys: jax.Array) -> jax.Array:
    """Packed int64 composite keys -> (N, 3) int32 lexicographic columns."""
    k = keys.astype(jnp.int64)
    mask = jnp.int64(MAX_ID)
    # INF_KEY padding maps to all-max columns (stays a +inf sentinel)
    c0 = jnp.minimum((k >> (2 * BITS)) & ((1 << 22) - 1), MAX_ID + 1)
    c1 = (k >> BITS) & mask
    c2 = k & mask
    return jnp.stack([c0, c1, c2], -1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret", "block_k", "block_q"))
def searchsorted(keys: jax.Array, queries: jax.Array, *,
                 interpret: bool = True, block_k: int = 2048,
                 block_q: int = 256) -> jax.Array:
    """Drop-in for jnp.searchsorted(keys, queries) on packed int64 keys."""
    return _ss.searchsorted3(unpack_to_cols(keys), unpack_to_cols(queries),
                             block_k=block_k, block_q=block_q,
                             interpret=interpret).astype(jnp.int64)


@functools.partial(jax.jit,
                   static_argnames=("cap", "flt_mask", "eq_positions",
                                    "interpret", "block_k", "block_q"))
def probe_gather(keys: jax.Array, lo: jax.Array, hi: jax.Array,
                 flt: jax.Array, *, cap: int,
                 flt_mask: tuple = (False, False, False),
                 eq_positions: tuple = (), interpret: bool = True,
                 block_k: int = 2048, block_q: int = 256):
    """Fused MAPSIN probe on packed int64 keys — drop-in for the jnp
    gather_range + apply_residual pair in core/mapsin.py `probe`.

    Returns (k (B, cap) int64 packed match keys, 0 where invalid;
    valid (B, cap) bool; missed (B,) int32)."""
    match3, valid, missed = _pg.probe_gather3(
        unpack_to_cols(keys), unpack_to_cols(lo), unpack_to_cols(hi),
        flt.astype(jnp.int32), cap=cap, flt_mask=flt_mask,
        eq_positions=eq_positions, block_k=block_k, block_q=block_q,
        interpret=interpret)
    k = ((match3[..., 0].astype(jnp.int64) << (2 * BITS))
         | (match3[..., 1].astype(jnp.int64) << BITS)
         | match3[..., 2].astype(jnp.int64))
    return jnp.where(valid, k, 0), valid, missed


@functools.partial(jax.jit,
                   static_argnames=("causal", "interpret", "block_q", "block_kv"))
def flash_attention(q, k, v, *, causal: bool = True, interpret: bool = True,
                    block_q: int = 512, block_kv: int = 512):
    return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_kv=block_kv, interpret=interpret)
