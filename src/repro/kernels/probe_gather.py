"""Pallas TPU kernel: fused MAPSIN probe — the index GET in one pass.

The MAPSIN inner loop (core/mapsin.py `probe`) was built from ~6 unfused
ops: two `searchsorted` launches (lo and hi ranks), a `(B, cap)` int64
gather, an `unpack3` into three more `(B, cap)` temporaries, and a chain of
residual-filter compares — every one a round trip through HBM.  This kernel
fuses rank-find, range gather, residual predicate push-down and per-probe
slot placement into a single pass over the sorted column-store, so the only
HBM traffic is the key stream in and the `(B, cap)` match block out.

Layout and algorithm (DESIGN.md §2, same substrate as searchsorted.py):

  * keys live as THREE int32 columns (index order) — TPU has no native
    int64 vectors; lexicographic compare on 3 x int32 is pure VPU.
  * grid = (Q blocks, K blocks), K minor, so each probe block walks the
    sorted index sequentially.  Two VMEM scratch accumulators carry
    rank(lo) and rank(hi) across key blocks.
  * sortedness gives block pruning via scalar bounds + `pl.when`
    (searchsorted.py's B-tree walk): a key block entirely below every
    probe's `lo` bumps both rank counters by `block_k` with no elementwise
    work; a block entirely at/above every `hi` is skipped outright.  Only
    boundary blocks pay the compare tile.
  * within a boundary block, a key at global position g belongs to probe
    q's match slot c = g - rank_q(lo) (matches of a sorted range are
    contiguous), so placement is a one-hot accumulation over the cap
    slots — no gather, no scatter, no host-visible intermediate.
    Residual equality filters (the HBase server-side predicate push-down)
    and intra-pattern variable repeats are applied in-register before a
    slot is marked valid.
  * per-probe overflow (`missed`) falls out of the final rank counters:
    max(rank(hi) - rank(lo) - cap, 0), written at the last key block.

VMEM per step: Bk*3 + 3*Bq*3 int32 + the (Bk x Bq) compare tile + the
(Bq, cap) match block.  Defaults (Bq=256, Bk=2048, cap<=128) ≈ 4.5 MB —
inside the ~16 MB budget.  The jnp path in core/mapsin.py remains the
validated reference (`impl="jnp"` vs `"pallas_interpret"`); equivalence is
asserted bit-exactly in tests/test_probe_gather.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _less3(a0, a1, a2, b0, b1, b2):
    """Lexicographic (a0,a1,a2) < (b0,b1,b2), elementwise."""
    return (a0 < b0) | ((a0 == b0) & ((a1 < b1) | ((a1 == b1) & (a2 < b2))))


_BIG = 1 << 30


def _kernel(k_ref, lo_ref, hi_ref, flt_ref, out0_ref, out1_ref, out2_ref,
            val_ref, miss_ref, rlo_ref, rhi_ref, *, block_k: int, cap: int,
            nk: int, flt_mask: tuple, eq_positions: tuple):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out0_ref[...] = jnp.zeros_like(out0_ref)
        out1_ref[...] = jnp.zeros_like(out1_ref)
        out2_ref[...] = jnp.zeros_like(out2_ref)
        val_ref[...] = jnp.zeros_like(val_ref)
        miss_ref[...] = jnp.zeros_like(miss_ref)
        rlo_ref[...] = jnp.zeros_like(rlo_ref)
        rhi_ref[...] = jnp.zeros_like(rhi_ref)

    ks = (k_ref[:, 0], k_ref[:, 1], k_ref[:, 2])
    los = (lo_ref[:, 0], lo_ref[:, 1], lo_ref[:, 2])
    his = (hi_ref[:, 0], hi_ref[:, 1], hi_ref[:, 2])

    # scalar block bounds (keys sorted; padding rows are +INF sentinels);
    # conservative on the leading component only, like searchsorted.py
    kmax = (ks[0][-1], ks[1][-1], ks[2][-1])
    kmin = (ks[0][0], ks[1][0], ks[2][0])
    blk_below = _less3(kmax[0], kmax[1], kmax[2],
                       jnp.min(los[0]), jnp.min(los[1]) * 0 - _BIG,
                       jnp.min(los[2]) * 0 - _BIG)
    blk_above = ~_less3(kmin[0], kmin[1], kmin[2],
                        jnp.max(his[0]), jnp.max(his[1]) * 0 + _BIG,
                        jnp.max(his[2]) * 0 + _BIG)

    @pl.when(blk_below)
    def _skip_low():  # every key < every lo: bump both rank carries
        rlo_ref[...] = rlo_ref[...] + block_k
        rhi_ref[...] = rhi_ref[...] + block_k

    @pl.when(jnp.logical_not(blk_below) & jnp.logical_not(blk_above))
    def _boundary():
        # (block_k, block_q) compare tiles
        lt_lo = _less3(ks[0][:, None], ks[1][:, None], ks[2][:, None],
                       los[0][None, :], los[1][None, :], los[2][None, :])
        lt_hi = _less3(ks[0][:, None], ks[1][:, None], ks[2][:, None],
                       his[0][None, :], his[1][None, :], his[2][None, :])
        n_lo = jnp.sum(lt_lo.astype(jnp.int32), axis=0).astype(jnp.int32)
        n_hi = jnp.sum(lt_hi.astype(jnp.int32), axis=0).astype(jnp.int32)
        # rank(lo) is complete once this block is counted: every key < lo
        # precedes every in-range key in the sorted order
        start = rlo_ref[...] + n_lo                          # (block_q,)
        in_range = jnp.logical_not(lt_lo) & lt_hi
        idx = j * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                     (lt_lo.shape[0], 1), 0)
        slot = idx - start[None, :]                          # (bk, bq)
        ok = in_range & (slot >= 0) & (slot < cap)
        # residual predicate push-down, evaluated in-register
        resid = jnp.ones_like(ok)
        for pos in range(3):
            if flt_mask[pos]:
                resid = resid & (ks[pos][:, None] == flt_ref[:, pos][None, :])
        for a, b in eq_positions:
            resid = resid & (ks[a] == ks[b])[:, None]
        hit = ok & resid

        def place(c, _):
            sel = hit & (slot == c)                          # (bk, bq)
            seli = sel.astype(jnp.int32)
            v0 = jnp.sum(seli * ks[0][:, None], axis=0).astype(jnp.int32)
            v1 = jnp.sum(seli * ks[1][:, None], axis=0).astype(jnp.int32)
            v2 = jnp.sum(seli * ks[2][:, None], axis=0).astype(jnp.int32)
            nv = jnp.sum(seli, axis=0).astype(jnp.int32)
            out0_ref[:, pl.ds(c, 1)] = out0_ref[:, pl.ds(c, 1)] + v0[:, None]
            out1_ref[:, pl.ds(c, 1)] = out1_ref[:, pl.ds(c, 1)] + v1[:, None]
            out2_ref[:, pl.ds(c, 1)] = out2_ref[:, pl.ds(c, 1)] + v2[:, None]
            val_ref[:, pl.ds(c, 1)] = val_ref[:, pl.ds(c, 1)] + nv[:, None]
            return 0

        jax.lax.fori_loop(0, cap, place, 0)
        rlo_ref[...] = start
        rhi_ref[...] = rhi_ref[...] + n_hi

    @pl.when(j == nk - 1)
    def _finish():
        miss_ref[...] = jnp.maximum(rhi_ref[...] - rlo_ref[...] - cap, 0)


def probe_gather3(keys3: jax.Array, lo3: jax.Array, hi3: jax.Array,
                  flt3: jax.Array, *, cap: int,
                  flt_mask: tuple = (False, False, False),
                  eq_positions: tuple = (),
                  block_k: int = 2048, block_q: int = 256,
                  interpret: bool = False):
    """Fused probe over a sorted 3-column store.

    keys3: (M, 3) int32 lexicographically sorted (pad with INT32_MAX rows);
    lo3/hi3: (B, 3) int32 per-probe [lo, hi) range endpoints; flt3: (B, 3)
    int32 residual equality values (active where flt_mask[pos]).

    Returns (match3 (B, cap, 3) int32, valid (B, cap) bool, missed (B,)
    int32): slot c of probe b holds the (c+1)-th key of b's range (0 where
    invalid), valid marks slots whose key also passes the residual filters,
    missed counts range entries beyond `cap` ('left' rank semantics,
    residual-independent — identical to the jnp gather_range contract).
    """
    m, b = keys3.shape[0], lo3.shape[0]
    pad_k = (-m) % block_k
    pad_b = (-b) % block_q
    if pad_k:
        keys3 = jnp.pad(keys3, ((0, pad_k), (0, 0)),
                        constant_values=jnp.iinfo(jnp.int32).max)
    if pad_b:
        pad = ((0, pad_b), (0, 0))
        lo3 = jnp.pad(lo3, pad)       # empty [0, 0) ranges
        hi3 = jnp.pad(hi3, pad)
        flt3 = jnp.pad(flt3, pad)
    nk = keys3.shape[0] // block_k
    nq = lo3.shape[0] // block_q
    bq = lo3.shape[0]
    out0, out1, out2, val, miss = pl.pallas_call(
        functools.partial(_kernel, block_k=block_k, cap=cap, nk=nk,
                          flt_mask=tuple(flt_mask),
                          eq_positions=tuple(eq_positions)),
        grid=(nq, nk),
        in_specs=[
            pl.BlockSpec((block_k, 3), lambda i, j: (j, 0)),
            pl.BlockSpec((block_q, 3), lambda i, j: (i, 0)),
            pl.BlockSpec((block_q, 3), lambda i, j: (i, 0)),
            pl.BlockSpec((block_q, 3), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, cap), lambda i, j: (i, 0)),
            pl.BlockSpec((block_q, cap), lambda i, j: (i, 0)),
            pl.BlockSpec((block_q, cap), lambda i, j: (i, 0)),
            pl.BlockSpec((block_q, cap), lambda i, j: (i, 0)),
            pl.BlockSpec((block_q,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bq, cap), jnp.int32),
            jax.ShapeDtypeStruct((bq, cap), jnp.int32),
            jax.ShapeDtypeStruct((bq, cap), jnp.int32),
            jax.ShapeDtypeStruct((bq, cap), jnp.int32),
            jax.ShapeDtypeStruct((bq,), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.int32),   # rank(lo) carry
            pltpu.VMEM((block_q,), jnp.int32),   # rank(hi) carry
        ],
        interpret=interpret,
    )(keys3, lo3, hi3, flt3)
    match3 = jnp.stack([out0, out1, out2], axis=-1)
    return match3[:b], (val[:b] > 0), miss[:b]
