"""repro — MAPSIN cascading map-side joins on TPU/JAX + multi-arch LM framework."""
import jax

# The join engine's composite triple keys are 63-bit (3 x 21-bit terms in one
# sorted int64 word — see core/rdf.py). All model code pins its dtypes
# explicitly (bf16/f32/int32), so enabling x64 only affects the key arrays.
jax.config.update("jax_enable_x64", True)
