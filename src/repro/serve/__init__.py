"""Query serving subsystem (DESIGN.md §5, §7): SPARQL BGP front-end +
batched multi-query executor on top of the MAPSIN probe engine, with the
robustness layer (overflow-escalation retries, deadlines, load shedding,
fault injection)."""
from repro.serve.sparql import ParsedQuery, parse_bgp  # noqa: F401
from repro.serve.engine import (  # noqa: F401
    EngineBusy, QueryResult, QueryShed, QueryTimeout, ServeEngine,
    plan_signature,
)
from repro.serve.faults import (  # noqa: F401
    DurabilityFaultPlan, Fault, FaultPlan, SimulatedCrash, WalFault,
)
