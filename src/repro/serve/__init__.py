"""Query serving subsystem (DESIGN.md §5): SPARQL BGP front-end +
batched multi-query executor on top of the MAPSIN probe engine."""
from repro.serve.sparql import ParsedQuery, parse_bgp  # noqa: F401
from repro.serve.engine import (  # noqa: F401
    EngineBusy, QueryResult, ServeEngine, plan_signature,
)
