"""Deterministic fault injection for the sharded serving path
(DESIGN.md §7).

A ``FaultPlan`` is a static, hashable description of which a2a ANSWER
legs misbehave and when: each ``Fault`` names the join step, the
answering shard, the kind (``drop`` — the shard's outgoing answer
blocks plus their checksums are zeroed, as if the packets were lost;
``corrupt`` — the answer keys are perturbed AFTER the checksum is
computed, i.e. wire corruption; ``delay`` — a host-side synthetic stall,
no device-side effect), and the dispatch **epoch** it fires on. The
engine counts physical dispatch attempts on a monotone epoch counter
(retries included), so a retry naturally advances past a one-shot
fault; ``period > 0`` makes the schedule repeat (``epoch % period``),
which is how a sampled plan injects a steady background fault RATE.

Everything is deterministic from the constructor arguments (or, via
``FaultPlan.sample``, from a seed): a chaos run is exactly
reproducible, and because the active faults of one epoch are
compile-time constants of the dispatched cascade, distinct fault
patterns compile distinct cascades while the (dominant) clean epochs
all share the one checked cascade.

Detection lives in ``core/distributed._dist_probe_a2a``: with
``with_check=True`` every answering shard ships a salted positional
checksum per outgoing answer block alongside the answer leg, and the
origin recomputes it over what actually arrived. A mismatched block is
ZEROED before any of its keys can enter a Bindings row (no wrong rows,
ever — at worst rows are missing pending the retry) and counted into
the ``bad`` output the engine's dispatch loop retries on.
"""
from __future__ import annotations

import dataclasses

import numpy as np

KINDS = ("drop", "corrupt", "delay")


class SimulatedCrash(Exception):
    """Raised by durability fault injection at the exact byte boundary a
    real crash would occupy. The store object that raised it must be
    abandoned (as a dead process's heap would be) and re-opened from
    disk — recovery is the code under test."""


@dataclasses.dataclass(frozen=True)
class WalFault:
    """One injected durability fault, fired when WAL record `record` is
    appended (absolute sequence number — numbering continues across WAL
    rotations, so a fault can target a post-compaction record).

    Effect, in order:
      1. ``lose_unsynced`` — previously appended-but-unsynced bytes are
         discarded (a power loss before the page cache hit disk: the
         partial-fsync scenario);
      2. the first ``torn_bytes`` bytes of the new record's frame are
         written and made durable (a torn write — 0 means the record
         never reached disk at all);
      3. :class:`SimulatedCrash` is raised BEFORE the ack, so the
         injected record (and anything lost in step 1) was never
         acknowledged and recovery must not surface it.
    """
    record: int
    torn_bytes: int = 0
    lose_unsynced: bool = False


@dataclasses.dataclass(frozen=True)
class DurabilityFaultPlan:
    """Static, seedable schedule of WAL faults — the durability twin of
    :class:`FaultPlan`. Hooked by ``store.wal.WalWriter``: ``on_append``
    is consulted per record, ``on_sync`` per fsync. The first firing
    fault raises :class:`SimulatedCrash` (a crashed process injects at
    most one crash), so a plan normally carries one fault."""
    faults: tuple[WalFault, ...] = ()

    def _find(self, seq: int) -> WalFault | None:
        for f in self.faults:
            if f.record == seq:
                return f
        return None

    def on_append(self, seq: int, rec: bytes, writer) -> bytes:
        """Called by WalWriter.append with the framed record bytes before
        they are written; returns them unchanged when no fault fires."""
        f = self._find(seq)
        if f is None:
            return rec
        if f.lose_unsynced:
            writer.drop_unsynced()
        torn = rec[:max(0, min(f.torn_bytes, len(rec)))]
        if torn:
            # the prefix that made it to disk before the lights went out
            writer._f.write(torn)
            writer._f.flush()
        writer._f.close()
        raise SimulatedCrash(
            f"crash at WAL record {seq} (torn_bytes={len(torn)}, "
            f"lose_unsynced={f.lose_unsynced})")

    def on_sync(self, writer) -> None:
        """Sync-time hook (currently a pass-through; crash points are
        expressed per-record via ``on_append``)."""

    def any_fault(self) -> bool:
        return bool(self.faults)

    @classmethod
    def sample(cls, seed: int, horizon: int = 16,
               max_torn: int = 64) -> "DurabilityFaultPlan":
        """One seeded crash somewhere in the next `horizon` records:
        uniformly chosen record, torn prefix length in [0, max_torn],
        fair-coin unsynced-byte loss. Deterministic from the seed."""
        rng = np.random.RandomState(seed)
        return cls((WalFault(record=int(rng.randint(horizon)),
                             torn_bytes=int(rng.randint(max_torn + 1)),
                             lose_unsynced=bool(rng.randint(2))),))


@dataclasses.dataclass(frozen=True)
class Fault:
    """One injected fault on a shard's a2a answer leg."""
    step: int                   # join-step index (0 = first join step)
    shard: int                  # answering shard whose leg misbehaves
    kind: str                   # drop | corrupt | delay
    epoch: int = 0              # dispatch-attempt sequence number it fires on
    delay_s: float = 0.0        # synthetic stall (kind == "delay" only)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(one of {KINDS})")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A static, hashable schedule of injected faults.

    ``period > 0`` repeats the schedule every `period` epochs (faults
    match on ``epoch % period``); 0 means one-shot epochs. The plan is
    part of the engine's compile-cache key, so it must stay frozen and
    hashable."""
    faults: tuple[Fault, ...] = ()
    period: int = 0

    def _active(self, epoch: int):
        e = epoch % self.period if self.period > 0 else epoch
        return [f for f in self.faults if f.epoch == e]

    def at(self, epoch: int, step: int) -> tuple[tuple, tuple]:
        """(drop_shards, corrupt_shards) active for `step` at `epoch` —
        sorted tuples, the static per-step fault selection a compiled
        cascade embeds."""
        act = [f for f in self._active(epoch) if f.step == step]
        return (tuple(sorted(f.shard for f in act if f.kind == "drop")),
                tuple(sorted(f.shard for f in act if f.kind == "corrupt")))

    def selection(self, epoch: int, n_steps: int) -> tuple:
        """Per-join-step fault selection for one dispatch attempt: a
        hashable ``((drop...), (corrupt...))`` per step. All-empty on
        clean epochs — every clean epoch shares one compiled cascade."""
        return tuple(self.at(epoch, i) for i in range(n_steps))

    def delay_s_at(self, epoch: int) -> float:
        """Total synthetic stall injected at `epoch` (host-side: feeds
        the engine's dispatch watchdog and deadline accounting)."""
        return sum(f.delay_s for f in self._active(epoch)
                   if f.kind == "delay")

    def any_fault(self) -> bool:
        return bool(self.faults)

    @classmethod
    def sample(cls, seed: int, num_shards: int, n_steps: int = 2,
               rate: float = 0.01, horizon: int = 64,
               kinds: tuple[str, ...] = ("drop", "corrupt")) -> "FaultPlan":
        """Seeded Bernoulli(rate) fault per (epoch, step, shard) leg over
        a `horizon`-epoch repeating schedule — `rate` is the fraction of
        answer legs faulted in steady state. Deterministic: the same
        seed always yields the same plan."""
        rng = np.random.RandomState(seed)
        faults = []
        for e in range(horizon):
            for st in range(n_steps):
                for sh in range(num_shards):
                    if rng.rand() < rate:
                        faults.append(Fault(st, sh,
                                            kinds[rng.randint(len(kinds))],
                                            epoch=e))
        return cls(tuple(faults), period=horizon)
