"""SPARQL BGP front-end: query text -> ``Pattern`` tuples (DESIGN.md §5).

Covers the fragment the paper evaluates — SELECT over a basic graph
pattern — with PREFIX declarations, IRIs, prefixed names, plain literals
and the ``a`` shorthand for rdf:type. Everything outside that fragment
(FILTER, OPTIONAL, UNION, ...) is rejected with a clean ``ValueError``
naming the offending construct, as is any constant term that is not in
the store's ``Dictionary``: query parsing never mints dictionary ids
(``Dictionary.lookup``), so an unknown term fails fast at the front door
instead of silently matching nothing.
"""
from __future__ import annotations

import dataclasses
import re

from repro.core.rdf import Dictionary, Pattern

# SPARQL keywords outside the BGP fragment -> named rejection
_NON_BGP = frozenset({
    "FILTER", "OPTIONAL", "UNION", "GRAPH", "MINUS", "BIND", "VALUES",
    "ORDER", "GROUP", "HAVING", "LIMIT", "OFFSET", "DISTINCT", "REDUCED",
    "ASK", "CONSTRUCT", "DESCRIBE", "INSERT", "DELETE", "SERVICE",
})

_TOKEN = re.compile(r"""
    (?P<ws>\s+|\#[^\n]*)                    # whitespace / comment
  | (?P<var>\?[A-Za-z_]\w*)
  | (?P<iri><[^<>\s]*>)
  | (?P<lit>"[^"\n]*")
  | (?P<pname>[A-Za-z_][\w\-]*?:[\w\-]+(?:\.[\w\-]+)*|:[\w\-]+(?:\.[\w\-]+)*)
  | (?P<pfxdecl>[A-Za-z_][\w\-]*:|:)      # 'pfx:' in a PREFIX declaration
  | (?P<word>[A-Za-z_]\w*)
  | (?P<punct>[{}.*;()])
""", re.X)


@dataclasses.dataclass(frozen=True)
class ParsedQuery:
    patterns: tuple[Pattern, ...]
    select: tuple[str, ...]       # projected variables ('?x', ...)
    text: str

    @property
    def variables(self) -> tuple[str, ...]:
        seen: list[str] = []
        for p in self.patterns:
            for v in p.variables:
                if v not in seen:
                    seen.append(v)
        return tuple(seen)


def _tokenize(text: str) -> list[tuple[str, str]]:
    toks: list[tuple[str, str]] = []
    pos = 0
    while pos < len(text):
        m = _TOKEN.match(text, pos)
        if m is None:
            raise ValueError(f"SPARQL: cannot tokenize at {text[pos:pos+20]!r}")
        pos = m.end()
        kind = m.lastgroup
        if kind != "ws":
            toks.append((kind, m.group()))
    return toks


class _Cursor:
    def __init__(self, toks):
        self.toks, self.i = toks, 0

    def peek(self):
        return self.toks[self.i] if self.i < len(self.toks) else (None, "")

    def next(self, expect_kind=None, expect_val=None, what=""):
        kind, val = self.peek()
        if kind is None:
            raise ValueError(f"SPARQL: unexpected end of query, expected {what}")
        if expect_kind is not None and kind != expect_kind:
            raise ValueError(f"SPARQL: expected {what or expect_kind}, "
                             f"got {val!r}")
        if expect_val is not None and val.upper() != expect_val:
            raise ValueError(f"SPARQL: expected {expect_val}, got {val!r}")
        self.i += 1
        return kind, val


def _check_non_bgp(val: str):
    if val.upper() in _NON_BGP:
        raise ValueError(f"SPARQL: {val.upper()} is not supported "
                         "(BGP-only fragment)")


_SCRUB = re.compile(r'<[^<>\s]*>|"[^"\n]*"|\?\w+|[A-Za-z_][\w\-]*:[\w\-.]*')
_KEYWORDS = re.compile(r"\b(" + "|".join(sorted(_NON_BGP)) + r")\b", re.I)


def _reject_non_bgp(text: str):
    """Name the offending construct BEFORE tokenizing: FILTER bodies etc.
    contain characters the BGP tokenizer rejects, and 'cannot tokenize
    at >' is a much worse error than 'FILTER is not supported'. IRIs,
    literals, variables and prefixed names are scrubbed first so a term
    that merely contains a keyword doesn't false-positive."""
    m = _KEYWORDS.search(_SCRUB.sub(" ", text))
    if m:
        _check_non_bgp(m.group())


def _resolve_const(term_str: str, d: Dictionary, what: str) -> int:
    tid = d.lookup(term_str)
    if tid is None:
        raise ValueError(f"SPARQL: {what} {term_str!r} is not a term of "
                         "this dataset (undeclared term)")
    return tid


def parse_bgp(text: str, d: Dictionary) -> ParsedQuery:
    """Parse ``[PREFIX ...]* SELECT (?v... | *) WHERE { triples }`` into
    Patterns whose constants are resolved through ``d`` (read-only)."""
    _reject_non_bgp(text)
    cur = _Cursor(_tokenize(text))
    prefixes: dict[str, str] = {}

    # --- prologue: PREFIX declarations -------------------------------------
    while cur.peek()[0] == "word" and cur.peek()[1].upper() == "PREFIX":
        cur.next()
        kind, val = cur.next(what="prefix name ('pfx:')")
        if kind != "pfxdecl":
            raise ValueError(f"SPARQL: malformed PREFIX name {val!r}")
        name = val[:-1]
        k2, iri = cur.next(what="prefix IRI ('<...>')")
        if k2 != "iri":
            raise ValueError(f"SPARQL: PREFIX {name}: needs an <IRI>, "
                             f"got {iri!r}")
        prefixes[name] = iri[1:-1]

    # --- SELECT clause -----------------------------------------------------
    kind, val = cur.next(what="SELECT")
    if kind != "word" or val.upper() != "SELECT":
        _check_non_bgp(val)
        raise ValueError(f"SPARQL: expected SELECT, got {val!r}")
    select: list[str] = []
    star = False
    while True:
        kind, val = cur.peek()
        if kind == "var":
            select.append(val)
            cur.next()
        elif kind == "punct" and val == "*":
            star = True
            cur.next()
        else:
            break
    if not select and not star:
        raise ValueError("SPARQL: SELECT needs variables or *")

    kind, val = cur.next(what="WHERE")
    if kind != "word" or val.upper() != "WHERE":
        _check_non_bgp(val)
        raise ValueError(f"SPARQL: expected WHERE, got {val!r}")
    cur.next("punct", "{", what="'{'")

    # --- the BGP -----------------------------------------------------------
    def term(position: str):
        kind, val = cur.next(what=f"triple {position}")
        if kind == "var":
            return val
        if kind == "iri":
            return _resolve_const(val[1:-1], d, "IRI")
        if kind == "lit":
            return _resolve_const(val[1:-1], d, "literal")
        if kind == "pname":
            name, local = val.split(":", 1)
            if name not in prefixes:
                raise ValueError(f"SPARQL: unknown prefix {name!r}:"
                                 f" in {val!r}")
            return _resolve_const(prefixes[name] + local, d, "prefixed name")
        if kind == "word":
            if val == "a" and position == "predicate":
                return _resolve_const("rdf:type", d, "rdf:type ('a')")
            _check_non_bgp(val)
            raise ValueError(f"SPARQL: bare word {val!r} is not a valid "
                             f"triple {position}")
        raise ValueError(f"SPARQL: {val!r} is not a valid triple {position}")

    patterns: list[Pattern] = []
    while True:
        kind, val = cur.peek()
        if kind == "punct" and val == "}":
            cur.next()
            break
        if kind is None:
            raise ValueError("SPARQL: unterminated BGP (missing '}')")
        if kind == "word":
            _check_non_bgp(val)
        patterns.append(Pattern(term("subject"), term("predicate"),
                                term("object")))
        kind, val = cur.peek()
        if kind == "punct" and val in ".;":
            if val == ";":
                raise ValueError("SPARQL: predicate-object lists (';') are "
                                 "not supported; repeat the subject")
            cur.next()
    if not patterns:
        raise ValueError("SPARQL: empty basic graph pattern")
    if cur.peek()[0] is not None:
        _check_non_bgp(cur.peek()[1])
        raise ValueError(f"SPARQL: trailing input {cur.peek()[1]!r} after "
                         "the BGP (BGP-only fragment)")

    in_bgp: list[str] = []
    for p in patterns:
        for v in p.variables:
            if v not in in_bgp:
                in_bgp.append(v)
    if star:
        select = in_bgp
    for v in select:
        if v not in in_bgp:
            raise ValueError(f"SPARQL: selected variable {v} does not occur "
                             "in the BGP")
    return ParsedQuery(tuple(patterns), tuple(select), text)
