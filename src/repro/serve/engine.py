"""Batched multi-query executor (DESIGN.md §5).

The serving observation: a production query stream is many instances of
FEW plan shapes — the same BGP template with different constants (every
tenant asks "students of <their> department"). The engine exploits that:

* ``plan_signature`` canonicalizes a planned query into a **template**
  (variables renamed in first-occurrence order, every distinct constant
  replaced by a pre-bound pseudo-variable slot ``?_kN``) plus the slot
  value vector. Queries with equal templates differ only in constants.
* The template cascade seeds the initial Bindings domain with the const
  slots as already-bound columns, so the UNCHANGED core primitives
  (``mapsin_step`` / ``multiway_step`` — ``make_plan`` resolves a slot
  exactly like any bound variable) execute it; ``jax.vmap`` over the
  slot vector + per-slot donated scratch Bindings turns one compiled
  cascade into a whole batch of queries in ONE dispatch.
* A shape-bucketing scheduler groups the mixed request stream by
  template, pads each bucket to a power-of-two batch (bounded compile
  shapes), runs one bucket per ``step()``, and applies admission
  control: ``submit`` rejects with ``EngineBusy`` beyond ``max_queue``,
  a dispatch takes at most ``max_batch`` requests. Compiled batched
  cascades live in an ``LRUCache`` so a many-template tenant mix cannot
  grow compile memory forever.

Results are per-slot Bindings — bit-identical row sets to
``execute_local`` on the same (patterns, cfg), which tests verify
against ``execute_oracle`` as well. MAPSIN mode only: reduce-side
re-scans relations with an empty domain, which a seeded-constant
template cannot express.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mapsin as ms
from repro.core.bgp import ExecConfig, Step, plan_steps
from repro.core.mapsin import Bindings, apply_residual, compact
from repro.core.plan import make_plan, probe_ranges, residual_values
from repro.core.rdf import Pattern, is_var, unpack3
from repro.core.triple_store import LRUCache, TripleStore
from repro.serve.sparql import ParsedQuery, parse_bgp


class EngineBusy(RuntimeError):
    """Admission control: the request queue is at max_queue depth."""


@dataclasses.dataclass(frozen=True)
class Template:
    """Canonical plan shape: steps over renamed variables + const slots."""
    steps: tuple[Step, ...]
    const_vars: tuple[str, ...]     # ("?_k0", ...) pre-bound slot columns

    @property
    def n_consts(self) -> int:
        return len(self.const_vars)


def plan_signature(store: TripleStore, patterns: Sequence[Pattern],
                   cfg: ExecConfig, mode: str = "mapsin"):
    """Plan the query, then canonicalize the ordered steps.

    Returns ``(template, consts, var_order)``: the hashable Template (the
    bucket key — equal templates share one compiled batched cascade), the
    (n_consts,) int32 slot values, and the query's result variable order
    (original names, exactly ``execute_local``'s order). Repeated
    constants share a slot, which preserves multiway prefix[0] equality
    in the template exactly as in the concrete plan."""
    steps = tuple(plan_steps(patterns, cfg, store))
    rename: dict[str, str] = {}
    slots: dict[int, int] = {}
    const_vals: list[int] = []

    def sub(term):
        if is_var(term):
            if term not in rename:
                rename[term] = f"?v{len(rename)}"
            return rename[term]
        cid = int(term)
        if cid not in slots:
            slots[cid] = len(const_vals)
            const_vals.append(cid)
        return f"?_k{slots[cid]}"

    tsteps = tuple(
        Step(st.kind, tuple(Pattern(sub(p.s), sub(p.p), sub(p.o))
                            for p in st.patterns))
        for st in steps)
    var_order: list[str] = []
    for st in steps:
        for pat in st.patterns:
            var_order.extend(make_plan(pat, var_order).out_var_names)
    template = Template(tsteps, tuple(f"?_k{i}"
                                      for i in range(len(const_vals))))
    return template, np.asarray(const_vals, np.int32), tuple(var_order)


def _seed_scan(pattern: Pattern, const_vars: tuple[str, ...],
               keys: jnp.ndarray, consts: jnp.ndarray, out_cap: int,
               impl: str, scratch: Bindings) -> Bindings:
    """First-pattern scan with the constant slots as an already-bound
    domain: ``scan_pattern`` generalized from an empty domain to a 1-row
    seed table carrying the slot values. The scan range/residuals come
    from the seed row; the output table carries the slot columns along
    (broadcast) so every later step resolves them like bound variables.
    ``scratch`` (per-slot, donated by the jitted batch) is consumed.

    Fast path: a bound-prefix pattern with no residual filters is ONE
    range GET (searchsorted + out_cap-window gather) instead of a full
    pass over the key array — O(log N + cap) per batch slot, and
    row-for-row identical to the full scan: without residuals both take
    the first out_cap range entries in key order and surface the rest as
    overflow. Residual/equality filters force the full-scan path, where
    filtering must happen BEFORE the capacity cut — note that path
    materializes an O(N) row table PER BATCH SLOT under vmap, so
    scan-shaped first patterns are fine to serve occasionally but a
    stream of them on a large store wants small batches (it is also the
    one shape where batching buys nothing: the scan dominates)."""
    plan = make_plan(pattern, const_vars)
    seed = consts[None, :].astype(jnp.int32)           # (1, n_consts)
    lo, hi = probe_ranges(plan, seed)
    if plan.prefix and not plan.residual and not plan.eq_positions:
        k, valid, missed = ms.gather_range(keys, lo, hi, out_cap, impl)
        k, within = k[0], valid[0]                     # (out_cap,)
        dropped = missed[0]
    else:
        flt, msk = residual_values(plan, seed)
        within = (keys >= lo[0]) & (keys < hi[0])
        within = apply_residual(keys[None, :], within[None, :], flt, msk,
                                plan.eq_positions)[0]
        k, dropped = keys, None
    t = unpack3(k)
    n = k.shape[0]
    cols = ([jnp.broadcast_to(consts[i].astype(jnp.int32), (n,))[:, None]
             for i in range(len(const_vars))]
            + [t[pos].astype(jnp.int32)[:, None] for _, pos in plan.out_vars])
    rows = (jnp.concatenate(cols, axis=-1) if cols
            else jnp.zeros((n, 0), jnp.int32))
    table, vmask, ndrop = compact(rows, within, out_cap, buf=scratch.table)
    vmask = vmask | scratch.valid                      # zeros; consumes buffer
    overflow = ((dropped if dropped is not None else ndrop).astype(jnp.int32)
                + scratch.overflow)
    return Bindings(const_vars + plan.out_var_names, table, vmask, overflow)


@dataclasses.dataclass
class QueryResult:
    request_id: int
    vars: tuple[str, ...]           # result columns (execute_local's order)
    rows: np.ndarray                # (n_valid, n_vars) int32 valid rows
    overflow: int
    select: tuple[str, ...] | None = None   # SPARQL projection, if any

    def rows_set(self, var_order: Sequence[str] | None = None) -> set:
        vs = tuple(var_order) if var_order is not None else self.vars
        if not vs:
            return set([()] if len(self.rows) else [])
        perm = [self.vars.index(v) for v in vs]
        return set(tuple(int(r[i]) for i in perm) for r in self.rows)


@dataclasses.dataclass
class _Request:
    rid: int
    tid: int                        # interned template id (the bucket key)
    template: Template
    consts: np.ndarray
    var_order: tuple[str, ...]
    select: tuple[str, ...] | None
    arrival: float | None = None    # harness-stamped, for latency accounting


def _pow2_at_least(n: int) -> int:
    b = 1
    while b < n:
        b <<= 1
    return b


class ServeEngine:
    """Shape-bucketing batched query engine over one TripleStore.

    ``submit`` (SPARQL text, ParsedQuery, or a Pattern sequence) enqueues
    a request; ``step`` dispatches ONE batched cascade for the fullest
    template bucket; ``drain``/``execute`` run to completion. Results are
    per-request ``QueryResult``s whose row sets equal ``execute_local``.
    """

    def __init__(self, store: TripleStore, dictionary=None,
                 cfg: ExecConfig = ExecConfig(), mode: str = "mapsin",
                 max_batch: int = 32, max_queue: int = 256,
                 compile_cache_size: int = 32, starvation_limit: int = 4):
        if mode != "mapsin":
            raise ValueError("ServeEngine serves the MAPSIN path only "
                             "(reduce-side re-scans need an empty domain)")
        self.store, self.dictionary = store, dictionary
        self.cfg, self.mode = cfg, mode
        self.max_batch, self.max_queue = max_batch, max_queue
        self._compiled = LRUCache(compile_cache_size)
        self._signatures = LRUCache(max(4 * compile_cache_size, 64))
        # template interning: hashing a Template (a whole step tuple) per
        # scheduling decision is measurable python overhead at qps scale;
        # buckets key on a small int instead
        self._template_ids: dict[Template, int] = {}
        self._queue: deque[_Request] = deque()
        self._next_rid = 0
        self.starvation_limit = starvation_limit
        self._head_skips = 0            # consecutive steps the oldest
                                        # request's bucket was passed over
        self.dispatches = 0             # batched cascade invocations
        self.dispatched_queries = 0     # requests served by them

    # --- admission -------------------------------------------------------

    def pending(self) -> int:
        return len(self._queue)

    def submit(self, query, arrival: float | None = None) -> int:
        """Enqueue one query; returns its request id. Raises EngineBusy
        when the queue is at max_queue (admission control) and ValueError
        for malformed SPARQL / unknown terms (fail at the front door)."""
        select = None
        if isinstance(query, str):
            if self.dictionary is None:
                raise ValueError("SPARQL text needs a Dictionary-equipped "
                                 "engine (dictionary=...)")
            query = parse_bgp(query, self.dictionary)
        if isinstance(query, ParsedQuery):
            select = query.select
            patterns = tuple(query.patterns)
        else:
            patterns = tuple(query)
        if not patterns:
            raise ValueError("empty query")
        if len(self._queue) >= self.max_queue:
            raise EngineBusy(f"queue depth {len(self._queue)} at max_queue")
        sig_key = ("sig", patterns)
        hit = self._signatures.get(sig_key)
        if hit is None:
            template, consts, var_order = plan_signature(
                self.store, patterns, self.cfg, self.mode)
            tid = self._template_ids.setdefault(template,
                                                len(self._template_ids))
            hit = (tid, template, consts, var_order)
            self._signatures[sig_key] = hit
        tid, template, consts, var_order = hit
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(_Request(rid, tid, template, consts, var_order,
                                    select, arrival))
        return rid

    # --- batched execution ----------------------------------------------

    def _compiled_batch(self, tid: int, template: Template, batch: int):
        key = ("batched", tid, batch)
        hit = self._compiled.get(key)
        if hit is None:
            hit = self._build(template, batch)
            self._compiled[key] = hit
        return hit

    def _build(self, template: Template, batch: int):
        cfg = self.cfg
        steps, const_vars = template.steps, template.const_vars
        first = steps[0].patterns[0]
        first_plan = make_plan(first, const_vars)
        scratch_vars = const_vars + first_plan.out_var_names

        def one(keys_spo, keys_ops, consts, scratch):
            keys_of = lambda pat, dom: (
                keys_spo if make_plan(pat, dom).index == 0 else keys_ops)
            bnd = _seed_scan(first, const_vars, keys_of(first, const_vars),
                             consts, cfg.out_cap, cfg.impl, scratch)
            for st in steps[1:]:
                keys = keys_of(st.patterns[0], bnd.vars)
                if st.kind == "multiway":
                    bnd = ms.multiway_step(bnd, st.patterns, keys,
                                           cfg.row_cap, cfg.out_cap, cfg.impl)
                else:
                    bnd = ms.mapsin_step(bnd, st.patterns[0], keys,
                                         cfg.probe_cap, cfg.out_cap, cfg.impl)
            return bnd

        batched = jax.vmap(one, in_axes=(None, None, 0, 0))
        donate = (3,) if jax.default_backend() in ("tpu", "gpu") else ()
        return jax.jit(batched, donate_argnums=donate), scratch_vars

    def precompile(self, query, batches: Sequence[int] | None = None):
        """Compile (and warm) the query's template cascade for the given
        batch sizes — default every power of two up to max_batch — by
        running it on zeroed constants. A serving deployment calls this
        from a traffic log at startup so no live request ever waits on a
        compile (XLA compiles lazily at first call, so merely building
        the jitted wrapper would not warm anything)."""
        if isinstance(query, str):
            if self.dictionary is None:
                raise ValueError("SPARQL text needs a Dictionary-equipped "
                                 "engine (dictionary=...)")
            query = parse_bgp(query, self.dictionary)
        patterns = tuple(query.patterns if isinstance(query, ParsedQuery)
                         else query)
        template, _, _ = plan_signature(self.store, patterns, self.cfg,
                                        self.mode)
        tid = self._template_ids.setdefault(template, len(self._template_ids))
        if batches is None:
            batches = []
            b = 1
            while b <= self.max_batch:
                batches.append(b)
                b <<= 1
        for b in batches:
            jitted, scratch_vars = self._compiled_batch(tid, template, b)
            out = jitted(self.store.flat_keys(0), self.store.flat_keys(1),
                         jnp.zeros((b, template.n_consts), jnp.int32),
                         self._scratch(scratch_vars, b))
            jax.block_until_ready((out.table, out.valid, out.overflow))

    def _scratch(self, scratch_vars: tuple[str, ...], batch: int) -> Bindings:
        return Bindings(
            scratch_vars,
            jnp.zeros((batch, self.cfg.out_cap, len(scratch_vars)), jnp.int32),
            jnp.zeros((batch, self.cfg.out_cap), bool),
            jnp.zeros((batch,), jnp.int32))

    def _run_bucket(self, reqs: list[_Request]) -> list[QueryResult]:
        template = reqs[0].template
        n = len(reqs)
        batch = min(_pow2_at_least(n), self.max_batch)
        jitted, scratch_vars = self._compiled_batch(reqs[0].tid, template,
                                                    batch)
        consts = np.zeros((batch, template.n_consts), np.int32)
        for i, r in enumerate(reqs):
            consts[i] = r.consts
        for i in range(n, batch):                    # padding slots re-run
            consts[i] = reqs[0].consts               # request 0, discarded
        out = jitted(self.store.flat_keys(0), self.store.flat_keys(1),
                     jnp.asarray(consts), self._scratch(scratch_vars, batch))
        table = np.asarray(out.table)                # (batch, out_cap, nv)
        valid = np.asarray(out.valid)
        overflow = np.asarray(out.overflow)
        nk = template.n_consts
        self.dispatches += 1
        self.dispatched_queries += n
        results = []
        for i, r in enumerate(reqs):
            rows = table[i][valid[i]][:, nk:nk + len(r.var_order)]
            results.append(QueryResult(r.rid, r.var_order, rows,
                                       int(overflow[i]), r.select))
        return results

    # --- scheduling ------------------------------------------------------

    def step(self) -> list[QueryResult]:
        """Dispatch the fullest template bucket (at most max_batch
        requests) as one batched cascade; [] when the queue is empty.

        Anti-starvation aging: fullest-first alone would let a steady
        majority template starve a minority request forever. After the
        oldest queued request's bucket has been passed over
        `starvation_limit` consecutive steps, its bucket dispatches
        next regardless of size — latency is bounded by
        starvation_limit dispatches, throughput stays batch-greedy."""
        if not self._queue:
            return []
        buckets: dict[int, list[_Request]] = {}
        for r in self._queue:
            buckets.setdefault(r.tid, []).append(r)
        head_tid = self._queue[0].tid
        if self._head_skips >= self.starvation_limit:
            pick = buckets[head_tid]
        else:
            # fullest bucket first; FIFO within a bucket (deque order)
            pick = max(buckets.values(), key=len)
        chosen = pick[:self.max_batch]
        if chosen[0].tid == head_tid:
            self._head_skips = 0
        else:
            self._head_skips += 1
        taken = {r.rid for r in chosen}
        self._queue = deque(r for r in self._queue if r.rid not in taken)
        return self._run_bucket(chosen)

    def drain(self) -> list[QueryResult]:
        out: list[QueryResult] = []
        while self._queue:
            out.extend(self.step())
        return out

    def execute(self, queries) -> list[QueryResult]:
        """Submit + drain a closed batch, results in input order."""
        rids = [self.submit(q) for q in queries]
        by_rid = {res.request_id: res for res in self.drain()}
        return [by_rid[rid] for rid in rids]
