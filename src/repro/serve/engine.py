"""Batched multi-query executor (DESIGN.md §5).

The serving observation: a production query stream is many instances of
FEW plan shapes — the same BGP template with different constants (every
tenant asks "students of <their> department"). The engine exploits that:

* ``plan_signature`` canonicalizes a planned query into a **template**
  (variables renamed in first-occurrence order, every distinct constant
  replaced by a pre-bound pseudo-variable slot ``?_kN``) plus the slot
  value vector. Queries with equal templates differ only in constants.
* The template cascade seeds the initial Bindings domain with the const
  slots as already-bound columns, so the UNCHANGED core primitives
  (``mapsin_step`` / ``multiway_step`` — ``make_plan`` resolves a slot
  exactly like any bound variable) execute it; ``jax.vmap`` over the
  slot vector + per-slot donated scratch Bindings turns one compiled
  cascade into a whole batch of queries in ONE dispatch.
* A shape-bucketing scheduler groups the mixed request stream by
  template, pads each bucket to a power-of-two batch (bounded compile
  shapes), runs one bucket per ``step()``, and applies admission
  control: ``submit`` rejects with ``EngineBusy`` beyond ``max_queue``,
  a dispatch takes at most ``max_batch`` requests; a ``min_batch`` /
  ``max_wait_s`` policy (aging override) can defer sub-batch dispatches
  so capacity near saturation is not burned on tiny batches. Compiled
  batched cascades live in an ``LRUCache`` so a many-template tenant
  mix cannot grow compile memory forever.

* **Sharded serving** (the production shape, DESIGN.md §4/§5): with a
  ``mesh`` the engine lifts the template cascade under ``shard_map``
  over the region-sharded store. Each shard seeds the batch from its
  own key slice (vmapped seed scan — local), then every cascade step
  flattens the per-slot probe records of ALL queries in the batch,
  routes them via the stored region splits, and ships them with ONE
  ``all_to_all`` pair (``dist_probe_batched``) before a vmapped local
  merge scatters matches back to per-query slots — the batch shares
  the collective, not just the compilation. With ``routing="a2a"`` and
  ``caps.a2a_bucket_cap == 0`` every dispatch's caps come from the
  PLAN: ``compile_plan`` embeds the measured per-step a2a capacities
  (``planner.embed_a2a_caps``, cached per distinct query) and the
  engine only aggregates them per dispatch — per-destination probe
  buckets are the SUM of the members' embedded bucket caps (the exact
  drop-free bound) and the answer return legs the MAX of their
  embedded per-step answer caps, both quantized (``quantize_cap``) to
  bound compile diversity. The engine never calls a tune_* function.

Results are per-slot Bindings — bit-identical row sets to
``execute_local`` on the same (patterns, cfg, caps), which tests verify
against ``execute_oracle`` as well (sharded results keep ``out_cap``
rows PER SHARD, like ``execute_sharded``). MAPSIN operators only:
reduce-side re-scans relations with an empty domain, which a
seeded-constant template cannot express — the engine compiles with
``planner.ENGINE_OPERATORS``, so under a truncating cap budget (probe
fan-out beyond probe_cap) ``execute_local``'s unrestricted planner may
switch a step to the exact reduce_side fallback while the engine
truncates (and surfaces it in ``QueryResult.overflow`` / ``.stats``);
with non-truncating caps the row sets are identical.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mapsin as ms
from repro.core.bgp import ExecConfig, apply_dist_step, mesh_fingerprint
from repro.core.mapsin import Bindings, apply_residual, compact
from repro.core.plan import make_plan, probe_ranges, residual_values
from repro.core.planner import (ENGINE_OPERATORS, Caps, PhysicalPlan,
                                PlanStep, compile_plan, quantize_cap)
from repro.core.rdf import Pattern, is_var, unpack3
from repro.core.triple_store import LRUCache, TripleStore
from repro.serve.sparql import ParsedQuery, parse_bgp


class EngineBusy(RuntimeError):
    """Admission control: the request queue is at max_queue depth."""


@dataclasses.dataclass(frozen=True)
class Template:
    """Canonical plan shape: steps over renamed variables + const slots.
    Steps are ``planner.PlanStep``s whose caps are the engine's BASE
    budget — any per-query embedded (tuned) caps are stripped so that
    same-shape queries with different measured fan-outs still share one
    compiled batched cascade (the per-query values ride the requests and
    are aggregated per dispatch)."""
    steps: tuple[PlanStep, ...]
    const_vars: tuple[str, ...]     # ("?_k0", ...) pre-bound slot columns

    @property
    def n_consts(self) -> int:
        return len(self.const_vars)


def plan_signature(store: TripleStore, patterns: Sequence[Pattern],
                   cfg: ExecConfig = ExecConfig(), caps: Caps = Caps(),
                   mode: str = "mapsin", plan: PhysicalPlan | None = None):
    """Compile the query (cost-based planner, engine operator set), then
    canonicalize the ordered steps.

    Returns ``(template, consts, var_order)``: the hashable Template (the
    bucket key — equal templates share one compiled batched cascade), the
    (n_consts,) int32 slot values, and the query's result variable order
    (original names, exactly ``execute_local``'s order). Repeated
    constants share a slot, which preserves multiway prefix[0] equality
    in the template exactly as in the concrete plan."""
    if plan is None:
        plan = compile_plan(store, patterns, caps, mode=mode,
                            reorder=cfg.reorder,
                            operators=ENGINE_OPERATORS)
    rename: dict[str, str] = {}
    slots: dict[int, int] = {}
    const_vals: list[int] = []

    def sub(term):
        if is_var(term):
            if term not in rename:
                rename[term] = f"?v{len(rename)}"
            return rename[term]
        cid = int(term)
        if cid not in slots:
            slots[cid] = len(const_vals)
            const_vals.append(cid)
        return f"?_k{slots[cid]}"

    tsteps = tuple(
        PlanStep(st.kind, tuple(Pattern(sub(p.s), sub(p.p), sub(p.o))
                                for p in st.patterns), caps)
        for st in plan.steps)
    template = Template(tsteps, tuple(f"?_k{i}"
                                      for i in range(len(const_vals))))
    return template, np.asarray(const_vals, np.int32), plan.var_order


def _seed_scan(pattern: Pattern, const_vars: tuple[str, ...],
               keys: jnp.ndarray, consts: jnp.ndarray, out_cap: int,
               impl: str, scratch: Bindings) -> Bindings:
    """First-pattern scan with the constant slots as an already-bound
    domain: ``scan_pattern`` generalized from an empty domain to a 1-row
    seed table carrying the slot values. The scan range/residuals come
    from the seed row; the output table carries the slot columns along
    (broadcast) so every later step resolves them like bound variables.
    ``scratch`` (per-slot, donated by the jitted batch) is consumed.

    Fast path: a bound-prefix pattern with no residual filters is ONE
    range GET (searchsorted + out_cap-window gather) instead of a full
    pass over the key array — O(log N + cap) per batch slot, and
    row-for-row identical to the full scan: without residuals both take
    the first out_cap range entries in key order and surface the rest as
    overflow. Residual/equality filters force the full-scan path, where
    filtering must happen BEFORE the capacity cut — note that path
    materializes an O(N) row table PER BATCH SLOT under vmap, so
    scan-shaped first patterns are fine to serve occasionally but a
    stream of them on a large store wants small batches (it is also the
    one shape where batching buys nothing: the scan dominates)."""
    plan = make_plan(pattern, const_vars)
    seed = consts[None, :].astype(jnp.int32)           # (1, n_consts)
    lo, hi = probe_ranges(plan, seed)
    if plan.prefix and not plan.residual and not plan.eq_positions:
        k, valid, missed = ms.gather_range(keys, lo, hi, out_cap, impl)
        k, within = k[0], valid[0]                     # (out_cap,)
        dropped = missed[0]
    else:
        flt, msk = residual_values(plan, seed)
        within = (keys >= lo[0]) & (keys < hi[0])
        within = apply_residual(keys[None, :], within[None, :], flt, msk,
                                plan.eq_positions)[0]
        k, dropped = keys, None
    t = unpack3(k)
    n = k.shape[0]
    cols = ([jnp.broadcast_to(consts[i].astype(jnp.int32), (n,))[:, None]
             for i in range(len(const_vars))]
            + [t[pos].astype(jnp.int32)[:, None] for _, pos in plan.out_vars])
    rows = (jnp.concatenate(cols, axis=-1) if cols
            else jnp.zeros((n, 0), jnp.int32))
    table, vmask, ndrop = compact(rows, within, out_cap, buf=scratch.table)
    vmask = vmask | scratch.valid                      # zeros; consumes buffer
    overflow = ((dropped if dropped is not None else ndrop).astype(jnp.int32)
                + scratch.overflow)
    return Bindings(const_vars + plan.out_var_names, table, vmask, overflow)


@dataclasses.dataclass
class QueryResult:
    request_id: int
    vars: tuple[str, ...]           # result columns (execute_local's order)
    rows: np.ndarray                # (n_valid, n_vars) int32 valid rows
    overflow: int
    select: tuple[str, ...] | None = None   # SPARQL projection, if any
    stats: dict | None = None       # per-step execution stats from the
                                    # batched cascade: {"kinds": (...),
                                    # "overflow_per_step": (...)} — the
                                    # truncation counters that localize an
                                    # undersized cap to the step that
                                    # dropped rows (never silent)

    def rows_set(self, var_order: Sequence[str] | None = None) -> set:
        vs = tuple(var_order) if var_order is not None else self.vars
        if not vs:
            return set([()] if len(self.rows) else [])
        perm = [self.vars.index(v) for v in vs]
        return set(tuple(int(r[i]) for i in perm) for r in self.rows)


@dataclasses.dataclass
class _Request:
    rid: int
    tid: int                        # interned template id (the bucket key)
    template: Template
    consts: np.ndarray
    var_order: tuple[str, ...]
    select: tuple[str, ...] | None
    arrival: float | None = None    # harness-stamped, for latency accounting
    enq: float = 0.0                # enqueue clock (arrival if stamped, else
                                    # monotonic) — feeds the max_wait_s aging
    tuned: int = 0                  # this query's tuned a2a bucket cap
                                    # (0 = untuned / not applicable)
    step_caps: tuple | None = None  # measured per-join-step answer caps


def _pow2_at_least(n: int) -> int:
    b = 1
    while b < n:
        b <<= 1
    return b


class ServeEngine:
    """Shape-bucketing batched query engine over one TripleStore.

    ``submit`` (SPARQL text, ParsedQuery, or a Pattern sequence) enqueues
    a request; ``step`` dispatches ONE batched cascade for the fullest
    template bucket; ``drain``/``execute`` run to completion. Results are
    per-request ``QueryResult``s whose row sets equal ``execute_local``.

    With ``mesh`` (store sharded to the mesh size on ``axis``) every
    dispatch is ONE ``shard_map`` cascade against the region-sharded
    store; per-batch, not per-query, collective overhead (module
    docstring). ``min_batch``/``max_wait_s``: ``step`` defers while the
    fullest bucket is below ``min_batch`` UNLESS the oldest queued
    request has waited ``max_wait_s`` (then its bucket dispatches as-is)
    — latency-bounded batch aggregation; the defaults (1, 0.0) keep the
    greedy always-dispatch behavior.
    """

    def __init__(self, store: TripleStore, dictionary=None,
                 cfg: ExecConfig = ExecConfig(), caps: Caps = Caps(),
                 mode: str = "mapsin",
                 max_batch: int = 32, max_queue: int = 256,
                 compile_cache_size: int = 32, starvation_limit: int = 4,
                 mesh=None, axis: str = "data",
                 min_batch: int = 1, max_wait_s: float = 0.0):
        if mode != "mapsin":
            raise ValueError("ServeEngine serves the MAPSIN path only "
                             "(reduce-side re-scans need an empty domain)")
        if mesh is not None and store.num_shards != int(mesh.shape[axis]):
            raise ValueError(
                f"store has {store.num_shards} shards but mesh axis "
                f"{axis!r} has {int(mesh.shape[axis])} devices")
        if min_batch > max_batch:
            raise ValueError("min_batch cannot exceed max_batch")
        self.store, self.dictionary = store, dictionary
        self.cfg, self.caps, self.mode = cfg, caps, mode
        self.mesh, self.axis = mesh, axis
        self.max_batch, self.max_queue = max_batch, max_queue
        self.min_batch, self.max_wait_s = min_batch, max_wait_s
        self._compiled = LRUCache(compile_cache_size)
        self._signatures = LRUCache(max(4 * compile_cache_size, 64))
        # template interning: hashing a Template (a whole step tuple) per
        # scheduling decision is measurable python overhead at qps scale;
        # buckets key on a small int instead
        self._template_ids: dict[Template, int] = {}
        self._queue: deque[_Request] = deque()
        self._next_rid = 0
        self.starvation_limit = starvation_limit
        self._head_skips = 0            # consecutive steps the oldest
                                        # request's bucket was passed over
        self.dispatches = 0             # batched cascade invocations
        self.dispatched_queries = 0     # requests served by them
        self.a2a_payload_bytes = 0      # static per-shard a2a collective
                                        # payload shipped by dispatches

    # --- admission -------------------------------------------------------

    def pending(self) -> int:
        return len(self._queue)

    def submit(self, query, arrival: float | None = None) -> int:
        """Enqueue one query (SPARQL text, ParsedQuery, a compiled
        PhysicalPlan, or a Pattern sequence); returns its request id.
        Raises EngineBusy when the queue is at max_queue (admission
        control) and ValueError for malformed SPARQL / unknown terms /
        plans the template cascade cannot express (fail at the front
        door)."""
        select = None
        plan = None
        if isinstance(query, str):
            if self.dictionary is None:
                raise ValueError("SPARQL text needs a Dictionary-equipped "
                                 "engine (dictionary=...)")
            query = parse_bgp(query, self.dictionary)
        if isinstance(query, ParsedQuery):
            select = query.select
            patterns = tuple(query.patterns)
        elif isinstance(query, PhysicalPlan):
            if any(st.kind == "reduce_side" for st in query.steps):
                raise ValueError("a seeded template cascade cannot express "
                                 "reduce_side steps — compile the plan with "
                                 "planner.ENGINE_OPERATORS")
            # the engine executes templates at ITS base budget; a plan
            # compiled with a larger budget would silently truncate more
            # than its own caps promise — reject at the front door
            over = [(i, dim) for i, st in enumerate(query.steps)
                    for dim in ("out_cap", "scan_cap", "probe_cap",
                                "row_cap")
                    if getattr(st.caps, dim) > getattr(self.caps, dim)]
            if over:
                raise ValueError(
                    f"plan caps exceed the engine budget at {over[:3]} — "
                    f"build the engine with caps >= the plan's, or compile "
                    f"the plan with the engine's caps")
            plan = query
            patterns = query.patterns
        else:
            patterns = tuple(query)
        if not patterns:
            raise ValueError("empty query")
        if len(self._queue) >= self.max_queue:
            raise EngineBusy(f"queue depth {len(self._queue)} at max_queue")
        # cfg AND caps are part of the signature key: planning (ordering,
        # multiway grouping, embedded capacities) depends on both, so a
        # config change must re-plan; a user-supplied plan keys on itself
        sig_key = ("sig", plan if plan is not None else patterns,
                   self.cfg, self.caps)
        hit = self._signatures.get(sig_key)
        if hit is None:
            if plan is None:
                plan = self._compile(patterns)
            template, consts, var_order = plan_signature(
                self.store, patterns, self.cfg, self.caps, self.mode,
                plan=plan)
            tid = self._template_ids.setdefault(template,
                                                len(self._template_ids))
            tuned, step_caps = self._plan_caps(plan)
            hit = (tid, template, consts, var_order, tuned, step_caps)
            self._signatures[sig_key] = hit
        tid, template, consts, var_order, tuned, step_caps = hit
        rid = self._next_rid
        self._next_rid += 1
        enq = arrival if arrival is not None else time.monotonic()
        self._queue.append(_Request(rid, tid, template, consts, var_order,
                                    select, arrival, enq, tuned, step_caps))
        return rid

    # --- batched execution ----------------------------------------------

    def _compile(self, patterns) -> PhysicalPlan:
        """Compile the query with the engine's operator set. With a mesh,
        a2a routing, and an unpinned bucket cap, compile_plan embeds the
        measured a2a capacities into the plan's steps (one instrumented
        run per DISTINCT query, cached on the store — exactly the cost
        execute_sharded pays); the engine reads the caps off the plan,
        it never tunes anything itself."""
        num_shards = (self.store.num_shards
                      if (self.mesh is not None
                          and self.cfg.routing == "a2a"
                          and self.caps.a2a_bucket_cap == 0) else 0)
        return compile_plan(self.store, patterns, self.caps, mode=self.mode,
                            reorder=self.cfg.reorder,
                            operators=ENGINE_OPERATORS,
                            routing=self.cfg.routing, num_shards=num_shards)

    def _plan_caps(self, plan: PhysicalPlan) -> tuple:
        """Per-request capacity values read OFF the plan: (bucket cap,
        per-join-step answer caps). The bucket caps SUM across batch
        members (_bucket_cap_for), the answer caps MAX across them
        (_step_caps_for — the a2a return leg is per probe, so the widest
        member's embedded cap bounds everyone). ((0, None) when the plan
        carries no embedded a2a capacities.)"""
        if (self.mesh is None or self.cfg.routing != "a2a"
                or self.caps.a2a_bucket_cap > 0):
            return 0, None
        tuned = max((st.caps.a2a_bucket_cap for st in plan.steps[1:]),
                    default=0)
        step_caps = tuple(st.caps.row_cap if st.kind == "multiway"
                          else st.caps.probe_cap for st in plan.steps[1:])
        return tuned, step_caps

    def _bucket_cap_for(self, reqs: list, batch: int) -> int:
        """Per-destination a2a probe-bucket capacity for ONE dispatch: the
        SUM of the members' tuned caps (+ padding slots at the replicated
        request-0 cap), quantized. The sum is the exact drop-free bound
        for the batch — the per-(sender, region) load is at most
        sum_q L_q — and stays tight when queries of very different
        fan-outs share a template shape (the rdf:type-style heavy variant
        no longer inflates every sibling's dispatch the way a per-template
        max would). Clamped at batch x out_cap, the structural bound (a
        query never routes more probes than out_cap bindings per shard).
        """
        if self.mesh is None or self.cfg.routing != "a2a":
            return 0
        if self.caps.a2a_bucket_cap > 0:
            per_query = min(self.caps.a2a_bucket_cap, self.caps.out_cap)
            return batch * per_query
        # unembedded slots (possible only when a request was admitted under
        # a different config than it dispatches with) fall back to the
        # drop-free out_cap bound
        tuned = [r.tuned if r.tuned > 0 else self.caps.out_cap for r in reqs]
        total = sum(tuned) + (batch - len(reqs)) * (tuned[0] if tuned
                                                    else self.caps.out_cap)
        return min(quantize_cap(total), batch * self.caps.out_cap)

    def _step_caps_for(self, reqs: list, template: Template) -> tuple:
        """Per-join-step a2a answer caps for one dispatch: the MAX of the
        members' plan-embedded caps per step (quantized; a probe's
        answers are per probe, not per batch), min'd with the base
        probe/row caps — never looser than the budget, and falling back
        to it for unembedded members. Right-sizes the dominant return-leg
        payload: a point-probe step ships 8 key slots per routed probe
        instead of the configured probe_cap."""
        base_caps = tuple(st.caps.row_cap if st.kind == "multiway"
                          else st.caps.probe_cap
                          for st in template.steps[1:])
        if (self.mesh is None or self.cfg.routing != "a2a"
                or self.caps.a2a_bucket_cap > 0):
            return base_caps
        caps = list(base_caps)
        for i, dflt in enumerate(base_caps):
            embedded = [r.step_caps[i] for r in reqs
                        if r.step_caps is not None and i < len(r.step_caps)]
            if embedded and len(embedded) == len(reqs):
                caps[i] = min(quantize_cap(max(embedded)), dflt)
        return tuple(caps)

    def _payload_bytes(self, bucket_cap: int, step_caps: tuple) -> int:
        """Static per-shard a2a collective payload for one dispatch (same
        convention as benchmarks/bench_distributed: records out + answers
        back, the local diagonal block excluded — it never crosses the
        network)."""
        if self.mesh is None or self.cfg.routing != "a2a":
            return 0
        from repro.core.bgp import a2a_step_payload_bytes
        s = self.store.num_shards
        return sum(a2a_step_payload_bytes(bucket_cap, cap, s)
                   for cap in step_caps)

    def _compiled_batch(self, tid: int, template: Template, batch: int,
                        bucket_cap: int, step_caps: tuple):
        # full ExecConfig + mesh identity + store shard layout (+ the
        # resolved bucket/answer caps, compile-time constants) key the
        # cache: toggling routing/caps, re-pointing at a resharded store,
        # or re-sized buckets can never reuse a stale compiled cascade
        mesh_id = (None if self.mesh is None
                   else mesh_fingerprint(self.mesh, self.axis))
        key = ("batched", tid, batch, self.cfg, self.caps, mesh_id,
               self.store.layout_key, bucket_cap, step_caps)
        hit = self._compiled.get(key)
        if hit is None:
            hit = (self._build_sharded(template, batch, bucket_cap,
                                       step_caps)
                   if self.mesh is not None else self._build(template, batch))
            self._compiled[key] = hit
        return hit

    def _build(self, template: Template, batch: int):
        cfg = self.cfg
        steps, const_vars = template.steps, template.const_vars
        first = steps[0].patterns[0]
        first_plan = make_plan(first, const_vars)
        scratch_vars = const_vars + first_plan.out_var_names

        def one(keys_spo, keys_ops, consts, scratch):
            keys_of = lambda pat, dom: (
                keys_spo if make_plan(pat, dom).index == 0 else keys_ops)
            bnd = _seed_scan(first, const_vars, keys_of(first, const_vars),
                             consts, steps[0].caps.out_cap, cfg.impl,
                             scratch)
            ovfs = [bnd.overflow]
            for st in steps[1:]:
                c = st.caps
                keys = keys_of(st.patterns[0], bnd.vars)
                if st.kind == "multiway":
                    bnd = ms.multiway_step(bnd, st.patterns, keys,
                                           c.row_cap, c.out_cap, cfg.impl)
                else:
                    bnd = ms.mapsin_step(bnd, st.patterns[0], keys,
                                         c.probe_cap, c.out_cap, cfg.impl)
                ovfs.append(bnd.overflow)
            return bnd, jnp.stack(ovfs)          # cumulative, per step

        batched = jax.vmap(one, in_axes=(None, None, 0, 0))
        donate = (3,) if jax.default_backend() in ("tpu", "gpu") else ()
        return jax.jit(batched, donate_argnums=donate), scratch_vars

    def _build_sharded(self, template: Template, batch: int,
                       bucket_cap: int, step_caps: tuple):
        """The tentpole: one shard_map dispatch serves the whole batch
        against the region-sharded store. Inside the per-shard body the
        seed scan is vmapped over the batch against the LOCAL key slice
        (no collective — each shard seeds what it owns, exactly like
        execute_sharded's scan), then every cascade step routes the
        flattened per-slot probe records of ALL queries through ONE
        dist_probe collective round (apply_dist_step(batched=True)) and
        vmaps the merge back to per-query slots. Returns a jitted
        (keys_spo (S, cap), keys_ops (S, cap), consts (batch, n_consts))
        -> (table (S, batch, out_cap, nv), valid, overflow (S, batch))."""
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        cfg = self.cfg
        steps, const_vars = template.steps, template.const_vars
        # per-dispatch effective steps: the batch-aggregated a2a bucket cap
        # and the per-join-step answer caps are compile-time constants
        # embedded into each step's caps (apply_dist_step reads them there)
        eff_steps = [steps[0]] + [
            dataclasses.replace(st, caps=dataclasses.replace(
                st.caps, probe_cap=step_caps[i], row_cap=step_caps[i],
                a2a_bucket_cap=bucket_cap))
            for i, st in enumerate(steps[1:])]
        first = steps[0].patterns[0]
        first_plan = make_plan(first, const_vars)
        scratch_vars = const_vars + first_plan.out_var_names
        splits_spo = np.asarray(self.store.splits_spo)
        splits_ops = np.asarray(self.store.splits_ops)
        axis = self.axis

        def fn(keys_spo, keys_ops, consts):
            keys_spo = keys_spo.reshape(-1)
            keys_ops = keys_ops.reshape(-1)
            keys_of = lambda pat, dom: (
                keys_spo if make_plan(pat, dom).index == 0 else keys_ops)
            splits_of = lambda pat, dom: (
                splits_spo if make_plan(pat, dom).index == 0 else splits_ops)
            seed_keys = keys_of(first, const_vars)
            scr = self._scratch(scratch_vars, batch)
            bnd = jax.vmap(
                lambda c, s: _seed_scan(first, const_vars, seed_keys, c,
                                        steps[0].caps.out_cap, cfg.impl,
                                        s))(consts, scr)
            ovfs = [bnd.overflow]
            for st in eff_steps[1:]:
                keys = keys_of(st.patterns[0], bnd.vars)
                bnd = apply_dist_step(
                    bnd, st, keys, splits_of(st.patterns[0], bnd.vars),
                    cfg, axis, batched=True)
                ovfs.append(bnd.overflow)
            step_ovf = jnp.stack(ovfs)           # (n_steps, batch) cumulative
            return (bnd.table[None], bnd.valid[None], bnd.overflow[None],
                    step_ovf[None])

        sharded = shard_map(
            fn, mesh=self.mesh,
            in_specs=(P(axis, None), P(axis, None), P(None, None)),
            out_specs=(P(axis, None, None, None), P(axis, None, None),
                       P(axis, None), P(axis, None, None)),
            check_rep=False)
        return jax.jit(sharded), scratch_vars

    def _dispatch(self, tid: int, template: Template, batch: int,
                  consts: np.ndarray, bucket_cap: int, step_caps: tuple):
        """Run one compiled batched cascade; returns per-shard numpy views
        (tables (S, batch, out_cap, nv), valids (S, batch, out_cap),
        overflow (S, batch), step_ovf (S, batch, n_steps) cumulative) —
        S == 1 on the local (mesh-less) path."""
        jitted, scratch_vars = self._compiled_batch(tid, template, batch,
                                                    bucket_cap, step_caps)
        if self.mesh is None:
            out, step_ovf = jitted(self.store.flat_keys(0),
                                   self.store.flat_keys(1),
                                   jnp.asarray(consts),
                                   self._scratch(scratch_vars, batch))
            return (np.asarray(out.table)[None], np.asarray(out.valid)[None],
                    np.asarray(out.overflow)[None],
                    np.asarray(step_ovf)[None])
        t, v, o, so = jitted(self.store.keys_spo, self.store.keys_ops,
                             jnp.asarray(consts))
        self.a2a_payload_bytes += self._payload_bytes(bucket_cap, step_caps)
        # (S, n_steps, batch) -> (S, batch, n_steps)
        return (np.asarray(t), np.asarray(v), np.asarray(o),
                np.transpose(np.asarray(so), (0, 2, 1)))

    def precompile(self, query, batches: Sequence[int] | None = None):
        """Compile (and warm) the query's template cascade for the given
        batch sizes — default every power of two up to max_batch — by
        running it on zeroed constants. A serving deployment calls this
        from a traffic log at startup so no live request ever waits on a
        compile (XLA compiles lazily at first call, so merely building
        the jitted wrapper would not warm anything)."""
        if isinstance(query, str):
            if self.dictionary is None:
                raise ValueError("SPARQL text needs a Dictionary-equipped "
                                 "engine (dictionary=...)")
            query = parse_bgp(query, self.dictionary)
        patterns = tuple(query.patterns if isinstance(query, ParsedQuery)
                         else query)
        plan = self._compile(patterns)
        template, _, _ = plan_signature(self.store, patterns, self.cfg,
                                        self.caps, self.mode, plan=plan)
        tid = self._template_ids.setdefault(template, len(self._template_ids))
        tuned, step_caps = self._plan_caps(plan)
        if batches is None:
            batches = []
            b = 1
            while b <= self.max_batch:
                batches.append(b)
                b <<= 1
        payload0 = self.a2a_payload_bytes
        for b in batches:
            # warm the uniform-batch cap sizes for this query's tuned caps
            fake = [_Request(-1, tid, template, None, (), None, tuned=tuned,
                             step_caps=step_caps) for _ in range(b)]
            self._dispatch(tid, template, b,
                           np.zeros((b, template.n_consts), np.int32),
                           self._bucket_cap_for(fake, b),
                           self._step_caps_for(fake, template))
        self.a2a_payload_bytes = payload0      # warm-up ships no live traffic

    def _scratch(self, scratch_vars: tuple[str, ...], batch: int) -> Bindings:
        return Bindings(
            scratch_vars,
            jnp.zeros((batch, self.caps.out_cap, len(scratch_vars)),
                      jnp.int32),
            jnp.zeros((batch, self.caps.out_cap), bool),
            jnp.zeros((batch,), jnp.int32))

    def _run_bucket(self, reqs: list[_Request]) -> list[QueryResult]:
        template = reqs[0].template
        n = len(reqs)
        batch = min(_pow2_at_least(n), self.max_batch)
        consts = np.zeros((batch, template.n_consts), np.int32)
        for i, r in enumerate(reqs):
            consts[i] = r.consts
        for i in range(n, batch):                    # padding slots re-run
            consts[i] = reqs[0].consts               # request 0, discarded
        # (S, batch, out_cap, nv) per-shard tables; S == 1 without a mesh
        tables, valids, overflow, step_ovf = self._dispatch(
            reqs[0].tid, template, batch, consts,
            self._bucket_cap_for(reqs, batch),
            self._step_caps_for(reqs, template))
        nk = template.n_consts
        kinds = tuple(st.kind for st in template.steps)
        self.dispatches += 1
        self.dispatched_queries += n
        results = []
        for i, r in enumerate(reqs):
            rows = np.concatenate([tables[s, i][valids[s, i]]
                                   for s in range(tables.shape[0])]
                                  )[:, nk:nk + len(r.var_order)]
            # cumulative per-step counters summed over shards -> deltas:
            # which step dropped rows (probe vs out-cap truncation locale)
            cum = step_ovf[:, i, :].sum(axis=0)
            per_step = tuple(int(x) for x in np.diff(cum, prepend=0))
            stats = {"kinds": kinds, "overflow_per_step": per_step}
            results.append(QueryResult(r.rid, r.var_order, rows,
                                       int(overflow[:, i].sum()), r.select,
                                       stats))
        return results

    # --- scheduling ------------------------------------------------------

    def step(self, now: float | None = None,
             force: bool = False) -> list[QueryResult]:
        """Dispatch the fullest template bucket (at most max_batch
        requests) as one batched cascade; [] when the queue is empty.

        Dispatch policy (min_batch/max_wait_s): when the fullest bucket
        is below `min_batch`, the dispatch is DEFERRED (returns [] with
        requests still pending) so capacity near saturation is not burned
        on tiny batches — UNLESS the oldest queued request has already
        waited `max_wait_s` on the `now` clock (arrival-stamped requests
        use the harness clock, others time.monotonic), in which case its
        bucket dispatches as-is: the aging override bounds worst-case
        queueing latency at max_wait_s + one dispatch. `force=True`
        (drain) bypasses the policy. The defaults (min_batch=1) keep the
        greedy always-dispatch behavior.

        Anti-starvation aging: fullest-first alone would let a steady
        majority template starve a minority request forever. After the
        oldest queued request's bucket has been passed over
        `starvation_limit` consecutive steps, its bucket dispatches
        next regardless of size — latency is bounded by
        starvation_limit dispatches, throughput stays batch-greedy."""
        if not self._queue:
            return []
        buckets: dict[int, list[_Request]] = {}
        for r in self._queue:
            buckets.setdefault(r.tid, []).append(r)
        head_tid = self._queue[0].tid
        if self._head_skips >= self.starvation_limit:
            pick = buckets[head_tid]
        else:
            # fullest bucket first; FIFO within a bucket (deque order)
            pick = max(buckets.values(), key=len)
        if not force and len(pick) < self.min_batch:
            if now is None:
                now = time.monotonic()
            if now - self._queue[0].enq < self.max_wait_s:
                return []                 # defer: let the batch fill
            pick = buckets[head_tid]      # aged past max_wait_s: serve the
                                          # oldest request's bucket as-is
        chosen = pick[:self.max_batch]
        if chosen[0].tid == head_tid:
            self._head_skips = 0
        else:
            self._head_skips += 1
        taken = {r.rid for r in chosen}
        self._queue = deque(r for r in self._queue if r.rid not in taken)
        return self._run_bucket(chosen)

    def drain(self) -> list[QueryResult]:
        out: list[QueryResult] = []
        while self._queue:
            out.extend(self.step(force=True))
        return out

    def execute(self, queries) -> list[QueryResult]:
        """Submit + drain a closed batch, results in input order."""
        rids = [self.submit(q) for q in queries]
        by_rid = {res.request_id: res for res in self.drain()}
        return [by_rid[rid] for rid in rids]
