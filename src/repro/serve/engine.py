"""Batched multi-query executor (DESIGN.md §5).

The serving observation: a production query stream is many instances of
FEW plan shapes — the same BGP template with different constants (every
tenant asks "students of <their> department"). The engine exploits that:

* ``plan_signature`` canonicalizes a planned query into a **template**
  (variables renamed in first-occurrence order, every distinct constant
  replaced by a pre-bound pseudo-variable slot ``?_kN``) plus the slot
  value vector. Queries with equal templates differ only in constants.
* The template cascade seeds the initial Bindings domain with the const
  slots as already-bound columns, so the UNCHANGED core primitives
  (``mapsin_step`` / ``multiway_step`` — ``make_plan`` resolves a slot
  exactly like any bound variable) execute it; ``jax.vmap`` over the
  slot vector + per-slot donated scratch Bindings turns one compiled
  cascade into a whole batch of queries in ONE dispatch.
* A shape-bucketing scheduler groups the mixed request stream by
  template, pads each bucket to a power-of-two batch (bounded compile
  shapes), runs one bucket per ``step()``, and applies admission
  control: ``submit`` rejects with ``EngineBusy`` beyond ``max_queue``,
  a dispatch takes at most ``max_batch`` requests; a ``min_batch`` /
  ``max_wait_s`` policy (aging override) can defer sub-batch dispatches
  so capacity near saturation is not burned on tiny batches. Compiled
  batched cascades live in an ``LRUCache`` so a many-template tenant
  mix cannot grow compile memory forever.

* **Sharded serving** (the production shape, DESIGN.md §4/§5): with a
  ``mesh`` the engine lifts the template cascade under ``shard_map``
  over the region-sharded store. Each shard seeds the batch from its
  own key slice (vmapped seed scan — local), then every cascade step
  flattens the per-slot probe records of ALL queries in the batch,
  routes them via the stored region splits, and ships them with ONE
  ``all_to_all`` pair (``dist_probe_batched``) before a vmapped local
  merge scatters matches back to per-query slots — the batch shares
  the collective, not just the compilation. With ``routing="a2a"`` and
  ``caps.a2a_bucket_cap == 0`` every dispatch's caps come from the
  PLAN: ``compile_plan`` embeds the measured per-step a2a capacities
  (``planner.embed_a2a_caps``, cached per distinct query) and the
  engine only aggregates them per dispatch — per-destination probe
  buckets are the SUM of the members' embedded bucket caps (the exact
  drop-free bound) and the answer return legs the MAX of their
  embedded per-step answer caps, both quantized (``quantize_cap``) to
  bound compile diversity. The engine never calls a tune_* function.

* **Robustness layer** (DESIGN.md §7): a completed dispatch that
  reports nonzero overflow is not delivered truncated — the engine
  replans the query at geometrically escalated Caps (``escalate_caps``,
  bounded by ``max_escalations``) and re-enqueues it; the final attempt
  drops to the unrestricted planner's exact ``reduce_side`` fallback
  via ``execute_local``. Per-query deadlines shed expired queries with
  structured ``QueryTimeout`` results; a full queue sheds by priority
  (``QueryShed`` + ``retry_after``) before raising ``EngineBusy`` (which
  now carries the compiled plan and the hint); a seeded ``FaultPlan``
  injects drop/corrupt/delay faults into the a2a answer legs, which
  answer-leg checksums detect and the dispatch loop retries — wrong
  rows are structurally impossible (mismatched blocks are zeroed).

Results are per-slot Bindings — bit-identical row sets to
``execute_local`` on the same (patterns, cfg, caps), which tests verify
against ``execute_oracle`` as well (sharded results keep ``out_cap``
rows PER SHARD, like ``execute_sharded``). MAPSIN operators only:
reduce-side re-scans relations with an empty domain, which a
seeded-constant template cannot express — the engine compiles with
``planner.ENGINE_OPERATORS``, so under a truncating cap budget (probe
fan-out beyond probe_cap) ``execute_local``'s unrestricted planner may
switch a step to the exact reduce_side fallback while the engine
truncates (and surfaces it in ``QueryResult.overflow`` / ``.stats``);
with non-truncating caps the row sets are identical.
"""
from __future__ import annotations

import contextlib
import dataclasses
import logging
import time
from collections import deque
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mapsin as ms
from repro.core.bgp import (ExecConfig, apply_dist_step, execute_local,
                            mesh_fingerprint)
from repro.core.distributed import a2a_leg_bytes
from repro.core.mapsin import Bindings, apply_residual, compact
from repro.core.plan import make_plan, probe_ranges, residual_values
from repro.core.planner import (ALL_OPERATORS, ENGINE_OPERATORS, Caps,
                                PhysicalPlan, PlanStep, compile_plan,
                                escalate_caps, quantize_cap)
from repro.core.rdf import Pattern, is_var, unpack3
from repro.core.triple_store import LRUCache, TripleStore
from repro.obs import metrics as obs_metrics
from repro.obs.trace import Span, Tracer, spans_from_stats
from repro.serve.faults import FaultPlan
from repro.serve.sparql import ParsedQuery, parse_bgp

# engine lifecycle events (DESIGN.md §8): admission at DEBUG, shed /
# escalation / fallback / timeout at INFO, fault quarantine at WARNING.
# No handler is installed here — with default logging config the
# effective level is WARNING, so a healthy engine is silent.
log = logging.getLogger("repro.serve")


class EngineBusy(RuntimeError):
    """Admission control: the request queue is at max_queue depth and no
    queued request has strictly lower priority than the incoming one.

    Carries the planning work the rejection would otherwise waste:
    ``plan`` is the compiled PhysicalPlan (a client-side retry submits it
    directly and skips replanning — the signature cache then skips even
    the canonicalization) and ``retry_after`` is the engine's estimate in
    seconds of when a slot frees up (measured per-dispatch service time x
    queue depth in dispatches), 0.0 before any dispatch has been timed."""

    def __init__(self, msg: str, plan: PhysicalPlan | None = None,
                 retry_after: float = 0.0):
        super().__init__(msg)
        self.plan = plan
        self.retry_after = retry_after


@dataclasses.dataclass(frozen=True)
class Template:
    """Canonical plan shape: steps over renamed variables + const slots.
    Steps are ``planner.PlanStep``s whose caps are the engine's BASE
    budget — any per-query embedded (tuned) caps are stripped so that
    same-shape queries with different measured fan-outs still share one
    compiled batched cascade (the per-query values ride the requests and
    are aggregated per dispatch)."""
    steps: tuple[PlanStep, ...]
    const_vars: tuple[str, ...]     # ("?_k0", ...) pre-bound slot columns

    @property
    def n_consts(self) -> int:
        return len(self.const_vars)


def plan_signature(store: TripleStore, patterns: Sequence[Pattern],
                   cfg: ExecConfig = ExecConfig(), caps: Caps = Caps(),
                   mode: str = "mapsin", plan: PhysicalPlan | None = None):
    """Compile the query (cost-based planner, engine operator set), then
    canonicalize the ordered steps.

    Returns ``(template, consts, var_order)``: the hashable Template (the
    bucket key — equal templates share one compiled batched cascade), the
    (n_consts,) int32 slot values, and the query's result variable order
    (original names, exactly ``execute_local``'s order). Repeated
    constants share a slot, which preserves multiway prefix[0] equality
    in the template exactly as in the concrete plan."""
    if plan is None:
        plan = compile_plan(store, patterns, caps, mode=mode,
                            reorder=cfg.reorder,
                            operators=ENGINE_OPERATORS)
    rename: dict[str, str] = {}
    slots: dict[int, int] = {}
    const_vals: list[int] = []

    def sub(term):
        if is_var(term):
            if term not in rename:
                rename[term] = f"?v{len(rename)}"
            return rename[term]
        cid = int(term)
        if cid not in slots:
            slots[cid] = len(const_vals)
            const_vals.append(cid)
        return f"?_k{slots[cid]}"

    tsteps = tuple(
        PlanStep(st.kind, tuple(Pattern(sub(p.s), sub(p.p), sub(p.o))
                                for p in st.patterns), caps)
        for st in plan.steps)
    template = Template(tsteps, tuple(f"?_k{i}"
                                      for i in range(len(const_vals))))
    return template, np.asarray(const_vals, np.int32), plan.var_order


def _seed_scan(pattern: Pattern, const_vars: tuple[str, ...],
               keys: jnp.ndarray, consts: jnp.ndarray, out_cap: int,
               impl: str, scratch: Bindings) -> Bindings:
    """First-pattern scan with the constant slots as an already-bound
    domain: ``scan_pattern`` generalized from an empty domain to a 1-row
    seed table carrying the slot values. The scan range/residuals come
    from the seed row; the output table carries the slot columns along
    (broadcast) so every later step resolves them like bound variables.
    ``scratch`` (per-slot, donated by the jitted batch) is consumed.

    Fast path: a bound-prefix pattern with no residual filters is ONE
    range GET (searchsorted + out_cap-window gather) instead of a full
    pass over the key array — O(log N + cap) per batch slot, and
    row-for-row identical to the full scan: without residuals both take
    the first out_cap range entries in key order and surface the rest as
    overflow. Residual/equality filters force the full-scan path, where
    filtering must happen BEFORE the capacity cut — note that path
    materializes an O(N) row table PER BATCH SLOT under vmap, so
    scan-shaped first patterns are fine to serve occasionally but a
    stream of them on a large store wants small batches (it is also the
    one shape where batching buys nothing: the scan dominates)."""
    plan = make_plan(pattern, const_vars)
    seed = consts[None, :].astype(jnp.int32)           # (1, n_consts)
    lo, hi = probe_ranges(plan, seed)
    if plan.prefix and not plan.residual and not plan.eq_positions:
        k, valid, missed = ms.gather_range(keys, lo, hi, out_cap, impl)
        k, within = k[0], valid[0]                     # (out_cap,)
        dropped = missed[0]
    else:
        flt, msk = residual_values(plan, seed)
        within = (keys >= lo[0]) & (keys < hi[0])
        within = apply_residual(keys[None, :], within[None, :], flt, msk,
                                plan.eq_positions)[0]
        k, dropped = keys, None
    t = unpack3(k)
    n = k.shape[0]
    cols = ([jnp.broadcast_to(consts[i].astype(jnp.int32), (n,))[:, None]
             for i in range(len(const_vars))]
            + [t[pos].astype(jnp.int32)[:, None] for _, pos in plan.out_vars])
    rows = (jnp.concatenate(cols, axis=-1) if cols
            else jnp.zeros((n, 0), jnp.int32))
    table, vmask, ndrop = compact(rows, within, out_cap, buf=scratch.table)
    vmask = vmask | scratch.valid                      # zeros; consumes buffer
    overflow = ((dropped if dropped is not None else ndrop).astype(jnp.int32)
                + scratch.overflow)
    return Bindings(const_vars + plan.out_var_names, table, vmask, overflow)


@dataclasses.dataclass
class QueryResult:
    request_id: int
    vars: tuple[str, ...]           # result columns (execute_local's order)
    rows: np.ndarray                # (n_valid, n_vars) int32 valid rows
    overflow: int
    select: tuple[str, ...] | None = None   # SPARQL projection, if any
    stats: dict | None = None       # per-step execution stats from the
                                    # batched cascade: {"kinds": (...),
                                    # "overflow_per_step": (...)} — the
                                    # truncation counters that localize an
                                    # undersized cap to the step that
                                    # dropped rows (never silent)

    def rows_set(self, var_order: Sequence[str] | None = None) -> set:
        vs = tuple(var_order) if var_order is not None else self.vars
        if not vs:
            return set([()] if len(self.rows) else [])
        perm = [self.vars.index(v) for v in vs]
        return set(tuple(int(r[i]) for i in perm) for r in self.rows)


@dataclasses.dataclass
class QueryTimeout(QueryResult):
    """Structured deadline-expiry result (DESIGN.md §7): the query was
    SHED, not answered — ``rows`` is always empty, never a truncated row
    set masquerading as complete. ``phase`` says where the deadline hit
    ("queued" — expired before any dispatch; "dispatch" — the batched
    cascade it rode finished past the deadline, or tripped the engine
    watchdog; "escalation" — expired while re-queued for an
    overflow-escalation retry). ``stats`` carries the partial per-step
    counters of the last completed attempt, if any."""
    phase: str = "queued"
    deadline_s: float = 0.0         # the absolute deadline (enq clock)
    waited_s: float = 0.0           # time from enqueue to expiry


@dataclasses.dataclass
class QueryShed(QueryResult):
    """Load-shedding result: the request was evicted from a full queue by
    a strictly higher-priority submit. ``retry_after`` is the engine's
    service-time-based hint in seconds for when to resubmit."""
    retry_after: float = 0.0


@dataclasses.dataclass
class _Request:
    rid: int
    tid: int                        # interned template id (the bucket key)
    template: Template
    consts: np.ndarray
    var_order: tuple[str, ...]
    select: tuple[str, ...] | None
    arrival: float | None = None    # harness-stamped, for latency accounting
    enq: float = 0.0                # enqueue clock (arrival if stamped, else
                                    # monotonic) — feeds the max_wait_s aging
    tuned: int = 0                  # this query's tuned a2a bucket cap
                                    # (0 = untuned / not applicable)
    step_caps: tuple | None = None  # measured per-join-step answer caps
    patterns: tuple | None = None   # original patterns (escalation replans)
    ecaps: Caps | None = None       # effective caps this attempt runs at
    attempt: int = 0                # completed overflow escalations so far
    deadline: float | None = None   # absolute deadline on the enq clock
    tenant: str | None = None       # shedding accounting key
    priority: int = 0               # higher wins under a full queue
    inexact_ok: bool = False        # bounded-inexact opt-in: serve capped
                                    # results + counters, never escalate
    prior_stats: dict | None = None  # last attempt's stats (timeout payload)
    est_cost: float = 0.0           # planner's estimated cost (span attrs)
    span: Span | None = None        # open root "query" trace span, if any
    tq0: float = 0.0                # tracer-clock stamp of this rung's
                                    # queue entry (-1.0 once its "queued"
                                    # span has been emitted)


def _pow2_at_least(n: int) -> int:
    b = 1
    while b < n:
        b <<= 1
    return b


_RUNG_NAMES = tuple(f"rung{i}" for i in range(8))


def _rung_name(attempt: int) -> str:
    return (_RUNG_NAMES[attempt] if attempt < len(_RUNG_NAMES)
            else f"rung{attempt}")


# shared attrs dict for the per-query admission span: a successful submit
# carries no per-query payload (the root "query" span holds it), so every
# submit span can alias ONE dict instead of allocating its own
_SUBMIT_ATTRS: dict = {}


class ServeEngine:
    """Shape-bucketing batched query engine over one TripleStore.

    ``submit`` (SPARQL text, ParsedQuery, or a Pattern sequence) enqueues
    a request; ``step`` dispatches ONE batched cascade for the fullest
    template bucket; ``drain``/``execute`` run to completion. Results are
    per-request ``QueryResult``s whose row sets equal ``execute_local``.

    With ``mesh`` (store sharded to the mesh size on ``axis``) every
    dispatch is ONE ``shard_map`` cascade against the region-sharded
    store; per-batch, not per-query, collective overhead (module
    docstring). ``min_batch``/``max_wait_s``: ``step`` defers while the
    fullest bucket is below ``min_batch`` UNLESS the oldest queued
    request has waited ``max_wait_s`` (then its bucket dispatches as-is)
    — latency-bounded batch aggregation; the defaults (1, 0.0) keep the
    greedy always-dispatch behavior.
    """

    def __init__(self, store: TripleStore, dictionary=None,
                 cfg: ExecConfig = ExecConfig(), caps: Caps = Caps(),
                 mode: str = "mapsin",
                 max_batch: int = 32, max_queue: int = 256,
                 compile_cache_size: int = 32, starvation_limit: int = 4,
                 mesh=None, axis: str = "data",
                 min_batch: int = 1, max_wait_s: float = 0.0,
                 max_escalations: int = 3,
                 dispatch_timeout_s: float | None = None,
                 fault_plan: FaultPlan | None = None,
                 check_answers: bool | None = None,
                 fault_retries: int = 2,
                 tracer: Tracer | None = None,
                 metrics=None, name: str = "engine"):
        if mode != "mapsin":
            raise ValueError("ServeEngine serves the MAPSIN path only "
                             "(reduce-side re-scans need an empty domain)")
        if mesh is not None and store.num_shards != int(mesh.shape[axis]):
            raise ValueError(
                f"store has {store.num_shards} shards but mesh axis "
                f"{axis!r} has {int(mesh.shape[axis])} devices")
        if min_batch > max_batch:
            raise ValueError("min_batch cannot exceed max_batch")
        if fault_plan is not None and (mesh is None
                                       or cfg.routing != "a2a"):
            raise ValueError("fault injection hooks the a2a answer leg — "
                             "it needs a mesh and routing='a2a'")
        self.store, self.dictionary = store, dictionary
        self.cfg, self.caps, self.mode = cfg, caps, mode
        self.mesh, self.axis = mesh, axis
        self.max_batch, self.max_queue = max_batch, max_queue
        self.min_batch, self.max_wait_s = min_batch, max_wait_s
        self.max_escalations = max_escalations
        self.dispatch_timeout_s = dispatch_timeout_s
        self.fault_plan = fault_plan
        # answer-leg checksums ride every dispatch when faults are being
        # injected (or on explicit opt-in); the check is what turns an
        # injected fault into a detected-and-retried one
        self.check_answers = (check_answers if check_answers is not None
                              else fault_plan is not None)
        if self.check_answers and (mesh is None or cfg.routing != "a2a"):
            raise ValueError("answer-leg checksums need a mesh and "
                             "routing='a2a'")
        self.fault_retries = fault_retries
        # observability (DESIGN.md §8): `tracer` records query-lifecycle
        # spans (None = off — every hook is behind one `is not None`
        # test, so the default path does no extra work); `metrics` is the
        # registry lifecycle counters/histograms record into: None = the
        # process-global obs.REGISTRY, False = disabled (no-op registry),
        # or an explicit MetricsRegistry. Both are plain attributes — a
        # harness may attach/detach them on a warmed engine.
        self.tracer = tracer
        self.metrics_registry = (
            obs_metrics.REGISTRY if metrics is None
            else obs_metrics.NULL_REGISTRY if metrics is False else metrics)
        self.name = name
        self._step_span: Span | None = None
        self._t_first_dispatch: float | None = None
        self._t_last_dispatch: float | None = None
        self._compiled = LRUCache(compile_cache_size)
        self._signatures = LRUCache(max(4 * compile_cache_size, 64))
        # template interning: hashing a Template (a whole step tuple) per
        # scheduling decision is measurable python overhead at qps scale;
        # buckets key on a small int instead
        self._template_ids: dict[Template, int] = {}
        self._queue: deque[_Request] = deque()
        self._shed: list[QueryResult] = []   # shed/timeout results awaiting
                                             # delivery by the next step()
        self._next_rid = 0
        self.starvation_limit = starvation_limit
        self._head_skips = 0            # consecutive steps the oldest
                                        # request's bucket was passed over
        self.dispatches = 0             # batched cascade invocations
        self.dispatched_queries = 0     # requests served by them
        self.a2a_payload_bytes = 0      # static per-shard a2a collective
                                        # payload shipped by dispatches
        self._service_ewma = 0.0        # measured seconds per dispatch
        self.fault_epoch = 0            # monotone physical-dispatch counter
                                        # (faults key on it; retries advance)
        self.escalations = 0            # overflow-escalation re-dispatches
        self.fallbacks = 0              # exact reduce_side fallback runs
        self.timeouts = 0               # deadline-shed queries
        self.corrupt_detected = 0       # quarantined answer blocks seen
        self.fault_redispatches = 0     # dispatches retried on detection
        self.shed_by_tenant: dict = {}  # tenant -> evicted-request count

    # --- admission -------------------------------------------------------

    def pending(self) -> int:
        return len(self._queue)

    @property
    def metrics_registry(self):
        return self._metrics_registry

    @metrics_registry.setter
    def metrics_registry(self, reg) -> None:
        # the per-query fast path resolves each instrument ONCE and incs
        # through a direct handle (registry get-or-create is measurable at
        # qps scale); swapping registries invalidates those handles
        self._metrics_registry = reg
        self._m_requests: dict = {}      # tenant -> Counter
        self._m_tpl_hist: dict = {}      # tid -> latency Histogram
        self._m_ten_hist: dict = {}      # tenant -> latency Histogram
        self._m_depth = reg.gauge("serve_queue_depth")
        self._m_dispatches = reg.counter("serve_dispatches_total")
        self._m_disp_queries = reg.counter("serve_dispatched_queries_total")
        self._m_batch_hist = reg.histogram(
            "serve_batch_size", buckets=obs_metrics.DEFAULT_SIZE_BUCKETS)

    def metrics(self) -> dict:
        """JSON snapshot of the engine's metrics registry: counters,
        gauges, and histograms with estimated p50/p99 — per-template
        (``serve_template_latency_seconds``) and per-tenant
        (``serve_tenant_latency_seconds``) latency SLOs read straight
        off it. Refreshes the derived ``serve_qps`` gauge (dispatched
        queries over the first->last dispatch wall span) first. For
        Prometheus text exposition use
        ``engine.metrics_registry.to_prom_text()``. Empty when the
        engine was built with ``metrics=False``."""
        if (self._t_first_dispatch is not None
                and self._t_last_dispatch is not None
                and self._t_last_dispatch > self._t_first_dispatch):
            span = self._t_last_dispatch - self._t_first_dispatch
            self.metrics_registry.gauge("serve_qps", engine=self.name).set(
                self.dispatched_queries / span)
        return self.metrics_registry.to_dict()

    def _retry_after(self) -> float:
        """Resubmission hint in seconds: measured per-dispatch service
        time (EWMA) x queue depth in dispatches. 0.0 until a dispatch has
        been timed — an idle engine has nothing to wait for."""
        if self._service_ewma <= 0.0:
            return 0.0
        depth = max(1, -(-len(self._queue) // max(self.max_batch, 1)))
        return self._service_ewma * depth

    def _signature_for(self, patterns, caps: Caps, plan=None):
        """(tid, template, consts, var_order, tuned, step_caps, est_cost)
        for the query at a given cap budget, LRU-cached. cfg AND caps are
        part of the key: planning (ordering, multiway grouping, embedded
        capacities) depends on both, so a config change — or an
        overflow-escalated budget — must re-plan; a user-supplied plan
        keys on itself. est_cost is the planner's cost estimate, carried
        so traces can show estimated-vs-actual per query. The store's
        layout_key (which carries store_version) is part of the key too:
        a plan embeds MEASURED statistics and a2a capacities, so a
        post-ingest submit must re-plan rather than reuse a signature
        computed against the pre-ingest store."""
        sig_key = ("sig", plan if plan is not None else patterns,
                   self.cfg, caps, self.store.layout_key)
        hit = self._signatures.get(sig_key)
        self._last_plan_cached = hit is not None
        m = self.metrics_registry
        if hit is None:
            m.counter("serve_plan_cache_misses_total").inc()
            if plan is None:
                plan = self._compile(patterns, caps)
            template, consts, var_order = plan_signature(
                self.store, patterns, self.cfg, caps, self.mode, plan=plan)
            tid = self._template_ids.setdefault(template,
                                                len(self._template_ids))
            tuned, step_caps = self._plan_caps(plan, caps)
            hit = (tid, template, consts, var_order, tuned, step_caps,
                   float(plan.cost))
            self._signatures[sig_key] = hit
        else:
            m.counter("serve_plan_cache_hits_total").inc()
        return hit

    def submit(self, query, arrival: float | None = None,
               deadline_s: float | None = None, tenant: str | None = None,
               priority: int = 0, inexact_ok: bool = False) -> int:
        """Enqueue one query (SPARQL text, ParsedQuery, a compiled
        PhysicalPlan, or a Pattern sequence); returns its request id.
        Raises ValueError for malformed SPARQL / unknown terms / plans
        the template cascade cannot express (fail at the front door).

        QoS knobs (DESIGN.md §7): `deadline_s` bounds total time in the
        engine — an expired query is shed with a structured QueryTimeout
        instead of occupying batch slots. `priority` breaks admission
        ties under a full queue: instead of the EngineBusy cliff, a
        higher-priority submit evicts the lowest-priority queued request
        (delivered as a QueryShed result with a `retry_after` hint);
        equal-or-lower priority still raises EngineBusy — which now
        carries the compiled plan and the retry_after hint, so the
        rejected client's planning work is not wasted. `inexact_ok`
        opts into bounded-inexact degraded mode: an overflowed result is
        served as-is with its per-step overflow counters attached
        (stats["degraded"]) rather than escalated."""
        tr = self.tracer
        if tr is None:
            return self._submit(query, arrival, deadline_s, tenant,
                                priority, inexact_ok)
        t0 = tr.now()
        try:
            rid = self._submit(query, arrival, deadline_s, tenant,
                               priority, inexact_ok)
        except Exception as e:
            tr.record("submit", t0, tr.now(), outcome=type(e).__name__,
                      tenant=tenant)
            raise
        tr.spans.append(Span("submit", t0, tr.now(), "engine",
                             _SUBMIT_ATTRS))
        return rid

    def _submit(self, query, arrival: float | None = None,
                deadline_s: float | None = None, tenant: str | None = None,
                priority: int = 0, inexact_ok: bool = False) -> int:
        tr = self.tracer
        select = None
        plan = None
        if isinstance(query, str):
            if self.dictionary is None:
                raise ValueError("SPARQL text needs a Dictionary-equipped "
                                 "engine (dictionary=...)")
            query = parse_bgp(query, self.dictionary)
        if isinstance(query, ParsedQuery):
            select = query.select
            patterns = tuple(query.patterns)
        elif isinstance(query, PhysicalPlan):
            if any(st.kind == "reduce_side" for st in query.steps):
                raise ValueError("a seeded template cascade cannot express "
                                 "reduce_side steps — compile the plan with "
                                 "planner.ENGINE_OPERATORS")
            # the engine executes templates at ITS base budget; a plan
            # compiled with a larger budget would silently truncate more
            # than its own caps promise — reject at the front door
            over = [(i, dim) for i, st in enumerate(query.steps)
                    for dim in ("out_cap", "scan_cap", "probe_cap",
                                "row_cap")
                    if getattr(st.caps, dim) > getattr(self.caps, dim)]
            if over:
                raise ValueError(
                    f"plan caps exceed the engine budget at {over[:3]} — "
                    f"build the engine with caps >= the plan's, or compile "
                    f"the plan with the engine's caps")
            plan = query
            patterns = query.patterns
        else:
            patterns = tuple(query)
        if not patterns:
            raise ValueError("empty query")
        # signature BEFORE admission: a rejected submit still returns its
        # compiled plan (satellite: EngineBusy must not waste the planning
        # work), and the LRU keeps the cost at one dict probe on repeats
        tp0 = tr.now() if tr is not None else 0.0
        tid, template, consts, var_order, tuned, step_caps, est_cost = \
            self._signature_for(patterns, self.caps, plan=plan)
        if tr is not None and not self._last_plan_cached:
            # plan spans only where planning actually ran; a cache hit is
            # one dict probe, carried as `template` on the submit span
            tr.record("plan", tp0, tr.now(), template=tid,
                      est_cost=est_cost)
        m = self._metrics_registry
        if len(self._queue) >= self.max_queue:
            victim = None
            for r in self._queue:
                if r.priority < priority and (
                        victim is None
                        or (r.priority, -r.enq) < (victim.priority,
                                                   -victim.enq)):
                    victim = r
            if victim is None:
                m.counter("serve_busy_total").inc()
                log.info("busy: queue depth %d at max_queue (tenant=%s)",
                         len(self._queue), tenant)
                raise EngineBusy(
                    f"queue depth {len(self._queue)} at max_queue",
                    plan=(plan if plan is not None
                          else self._compile(patterns)),
                    retry_after=self._retry_after())
            # graceful degradation: evict the lowest-priority (most
            # recently enqueued among ties) request instead of cliffing
            self._queue.remove(victim)
            self._shed.append(QueryShed(
                victim.rid, victim.var_order,
                np.zeros((0, len(victim.var_order)), np.int32), 0,
                victim.select, victim.prior_stats,
                retry_after=self._retry_after()))
            self.shed_by_tenant[victim.tenant] = (
                self.shed_by_tenant.get(victim.tenant, 0) + 1)
            m.counter("serve_sheds_total", tenant=str(victim.tenant),
                      reason="priority").inc()
            log.info("shed rid=%d tenant=%s priority=%d (evicted by "
                     "priority=%d)", victim.rid, victim.tenant,
                     victim.priority, priority)
            if tr is not None and victim.span is not None:
                tv = tr.now()
                if victim.tq0 >= 0:
                    tr.record("queued", victim.tq0, tv, track="query",
                              parent=victim.span, async_id=victim.rid,
                              outcome="shed")
                tr.end(victim.span, outcome="shed")
                victim.span = None
        rid = self._next_rid
        self._next_rid += 1
        enq = arrival if arrival is not None else time.monotonic()
        deadline = None if deadline_s is None else enq + deadline_s
        root = None
        tq0 = 0.0
        if tr is not None:
            # root "query" span, opened inline (its "queued"/"rung"
            # children are materialized in bulk at dispatch time — the
            # per-query tracing budget is nanoseconds, DESIGN.md §8)
            attrs = {"template": tid, "tenant": tenant,
                     "est_cost": est_cost, "n_patterns": len(patterns)}
            if priority:
                attrs["priority"] = priority
            tq0 = tr.now()
            root = Span("query", tq0, None, "query", attrs, None, None, rid)
            tr._open[root.span_id] = root
        self._queue.append(_Request(
            rid, tid, template, consts, var_order, select, arrival, enq,
            tuned, step_caps, patterns=patterns, ecaps=self.caps,
            deadline=deadline, tenant=tenant, priority=priority,
            inexact_ok=inexact_ok, est_cost=est_cost, span=root, tq0=tq0))
        c = self._m_requests.get(tenant)
        if c is None:
            c = self._m_requests[tenant] = m.counter(
                "serve_requests_total", tenant=str(tenant))
        c.inc()
        self._m_depth.set(len(self._queue))
        log.debug("admit rid=%d template=t%d tenant=%s queue=%d",
                  rid, tid, tenant, len(self._queue))
        return rid

    # --- batched execution ----------------------------------------------

    def _compile(self, patterns, caps: Caps | None = None) -> PhysicalPlan:
        """Compile the query with the engine's operator set at `caps`
        (default: the engine's base budget; escalation passes the
        escalated one). With a mesh, a2a routing, and an unpinned bucket
        cap, compile_plan embeds the measured a2a capacities into the
        plan's steps (one instrumented run per DISTINCT query, cached on
        the store — exactly the cost execute_sharded pays); the engine
        reads the caps off the plan, it never tunes anything itself."""
        caps = self.caps if caps is None else caps
        num_shards = (self.store.num_shards
                      if (self.mesh is not None
                          and self.cfg.routing == "a2a"
                          and caps.a2a_bucket_cap == 0) else 0)
        return compile_plan(self.store, patterns, caps, mode=self.mode,
                            reorder=self.cfg.reorder,
                            operators=ENGINE_OPERATORS,
                            routing=self.cfg.routing, num_shards=num_shards)

    def _plan_caps(self, plan: PhysicalPlan,
                   caps: Caps | None = None) -> tuple:
        """Per-request capacity values read OFF the plan: (bucket cap,
        per-join-step answer caps). The bucket caps SUM across batch
        members (_bucket_cap_for), the answer caps MAX across them
        (_step_caps_for — the a2a return leg is per probe, so the widest
        member's embedded cap bounds everyone). ((0, None) when the plan
        carries no embedded a2a capacities.)"""
        caps = self.caps if caps is None else caps
        if (self.mesh is None or self.cfg.routing != "a2a"
                or caps.a2a_bucket_cap > 0):
            return 0, None
        tuned = max((st.caps.a2a_bucket_cap for st in plan.steps[1:]),
                    default=0)
        step_caps = tuple(st.caps.row_cap if st.kind == "multiway"
                          else st.caps.probe_cap for st in plan.steps[1:])
        return tuned, step_caps

    def _bucket_cap_for(self, reqs: list, batch: int) -> int:
        """Per-destination a2a probe-bucket capacity for ONE dispatch: the
        SUM of the members' tuned caps (+ padding slots at the replicated
        request-0 cap), quantized. The sum is the exact drop-free bound
        for the batch — the per-(sender, region) load is at most
        sum_q L_q — and stays tight when queries of very different
        fan-outs share a template shape (the rdf:type-style heavy variant
        no longer inflates every sibling's dispatch the way a per-template
        max would). Clamped at batch x out_cap, the structural bound (a
        query never routes more probes than out_cap bindings per shard).
        """
        ecaps = (reqs[0].ecaps if reqs and reqs[0].ecaps is not None
                 else self.caps)
        if self.mesh is None or self.cfg.routing != "a2a":
            return 0
        if ecaps.a2a_bucket_cap > 0:
            per_query = min(ecaps.a2a_bucket_cap, ecaps.out_cap)
            return batch * per_query
        # unembedded slots (possible only when a request was admitted under
        # a different config than it dispatches with) fall back to the
        # drop-free out_cap bound
        tuned = [r.tuned if r.tuned > 0 else ecaps.out_cap for r in reqs]
        total = sum(tuned) + (batch - len(reqs)) * (tuned[0] if tuned
                                                    else ecaps.out_cap)
        return min(quantize_cap(total), batch * ecaps.out_cap)

    def _step_caps_for(self, reqs: list, template: Template) -> tuple:
        """Per-join-step a2a answer caps for one dispatch: the MAX of the
        members' plan-embedded caps per step (quantized; a probe's
        answers are per probe, not per batch), min'd with the base
        probe/row caps — never looser than the budget, and falling back
        to it for unembedded members. Right-sizes the dominant return-leg
        payload: a point-probe step ships 8 key slots per routed probe
        instead of the configured probe_cap."""
        ecaps = (reqs[0].ecaps if reqs and reqs[0].ecaps is not None
                 else self.caps)
        base_caps = tuple(st.caps.row_cap if st.kind == "multiway"
                          else st.caps.probe_cap
                          for st in template.steps[1:])
        if (self.mesh is None or self.cfg.routing != "a2a"
                or ecaps.a2a_bucket_cap > 0):
            return base_caps
        caps = list(base_caps)
        for i, dflt in enumerate(base_caps):
            embedded = [r.step_caps[i] for r in reqs
                        if r.step_caps is not None and i < len(r.step_caps)]
            if embedded and len(embedded) == len(reqs):
                caps[i] = min(quantize_cap(max(embedded)), dflt)
        return tuple(caps)

    def _payload_bytes(self, bucket_cap: int, step_caps: tuple) -> int:
        """Static per-shard a2a collective payload for one dispatch (same
        convention as benchmarks/bench_distributed: records out + answers
        back, the local diagonal block excluded — it never crosses the
        network)."""
        if self.mesh is None or self.cfg.routing != "a2a":
            return 0
        from repro.core.bgp import a2a_step_payload_bytes
        s = self.store.num_shards
        return sum(a2a_step_payload_bytes(bucket_cap, cap, s)
                   for cap in step_caps)

    def _compiled_batch(self, tid: int, template: Template, batch: int,
                        bucket_cap: int, step_caps: tuple,
                        fsel=None, with_check: bool = False):
        # full ExecConfig + mesh identity + store shard layout (+ the
        # resolved bucket/answer caps and fault selection, compile-time
        # constants) key the cache: toggling routing/caps, re-pointing at
        # a resharded store, re-sized buckets, or a different injected
        # fault pattern can never reuse a stale compiled cascade. Clean
        # epochs all carry fsel=None — they share ONE checked cascade.
        mesh_id = (None if self.mesh is None
                   else mesh_fingerprint(self.mesh, self.axis))
        key = ("batched", tid, batch, self.cfg, self.caps, mesh_id,
               self.store.layout_key, bucket_cap, step_caps, fsel,
               with_check)
        hit = self._compiled.get(key)
        m = self.metrics_registry
        if hit is None:
            m.counter("serve_compile_cache_misses_total").inc()
            tr = self.tracer
            tc0 = tr.now() if tr is not None else 0.0
            hit = (self._build_sharded(template, batch, bucket_cap,
                                       step_caps, fsel, with_check)
                   if self.mesh is not None else self._build(template, batch))
            if tr is not None:
                # the jit wrapper build; XLA's lazy compile lands inside
                # the first dispatch span that uses it
                tr.record("compile", tc0, tr.now(), track="engine",
                          parent=self._step_span, template=tid, batch=batch)
            self._compiled[key] = hit
            m.gauge("serve_compile_cache_size").set(len(self._compiled))
        else:
            m.counter("serve_compile_cache_hits_total").inc()
        return hit

    def _build(self, template: Template, batch: int):
        cfg = self.cfg
        steps, const_vars = template.steps, template.const_vars
        first = steps[0].patterns[0]
        first_plan = make_plan(first, const_vars)
        scratch_vars = const_vars + first_plan.out_var_names

        def one(keys_spo, keys_ops, consts, scratch):
            keys_of = lambda pat, dom: (
                keys_spo if make_plan(pat, dom).index == 0 else keys_ops)
            bnd = _seed_scan(first, const_vars, keys_of(first, const_vars),
                             consts, steps[0].caps.out_cap, cfg.impl,
                             scratch)
            ovfs = [bnd.overflow]
            for st in steps[1:]:
                c = st.caps
                keys = keys_of(st.patterns[0], bnd.vars)
                if st.kind == "multiway":
                    bnd = ms.multiway_step(bnd, st.patterns, keys,
                                           c.row_cap, c.out_cap, cfg.impl)
                else:
                    bnd = ms.mapsin_step(bnd, st.patterns[0], keys,
                                         c.probe_cap, c.out_cap, cfg.impl)
                ovfs.append(bnd.overflow)
            return bnd, jnp.stack(ovfs)          # cumulative, per step

        batched = jax.vmap(one, in_axes=(None, None, 0, 0))
        donate = (3,) if jax.default_backend() in ("tpu", "gpu") else ()
        return jax.jit(batched, donate_argnums=donate), scratch_vars

    def _build_sharded(self, template: Template, batch: int,
                       bucket_cap: int, step_caps: tuple,
                       fsel=None, with_check: bool = False):
        """The tentpole: one shard_map dispatch serves the whole batch
        against the region-sharded store. Inside the per-shard body the
        seed scan is vmapped over the batch against the LOCAL key slice
        (no collective — each shard seeds what it owns, exactly like
        execute_sharded's scan), then every cascade step routes the
        flattened per-slot probe records of ALL queries through ONE
        dist_probe collective round (apply_dist_step(batched=True)) and
        vmaps the merge back to per-query slots. Returns a jitted
        (keys_spo (S, cap), keys_ops (S, cap), consts (batch, n_consts))
        -> (table (S, batch, out_cap, nv), valid, overflow (S, batch),
        step_ovf, bad (S,)).

        `fsel`/`with_check` (DESIGN.md §7): fsel is the per-join-step
        static fault selection of ONE dispatch epoch (serve/faults.py);
        with_check adds the answer-leg checksum verify, whose per-shard
        quarantined-block count is summed into the `bad` output the
        dispatch loop retries on. Both are compile-time constants of the
        cascade."""
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        cfg = self.cfg
        steps, const_vars = template.steps, template.const_vars
        # per-dispatch effective steps: the batch-aggregated a2a bucket cap
        # and the per-join-step answer caps are compile-time constants
        # embedded into each step's caps (apply_dist_step reads them there)
        eff_steps = [steps[0]] + [
            dataclasses.replace(st, caps=dataclasses.replace(
                st.caps, probe_cap=step_caps[i], row_cap=step_caps[i],
                a2a_bucket_cap=bucket_cap))
            for i, st in enumerate(steps[1:])]
        first = steps[0].patterns[0]
        first_plan = make_plan(first, const_vars)
        scratch_vars = const_vars + first_plan.out_var_names
        splits_spo = np.asarray(self.store.splits_spo)
        splits_ops = np.asarray(self.store.splits_ops)
        axis = self.axis
        out_cap = steps[0].caps.out_cap

        def fn(keys_spo, keys_ops, consts):
            keys_spo = keys_spo.reshape(-1)
            keys_ops = keys_ops.reshape(-1)
            keys_of = lambda pat, dom: (
                keys_spo if make_plan(pat, dom).index == 0 else keys_ops)
            splits_of = lambda pat, dom: (
                splits_spo if make_plan(pat, dom).index == 0 else splits_ops)
            seed_keys = keys_of(first, const_vars)
            scr = self._scratch(scratch_vars, batch, out_cap)
            bnd = jax.vmap(
                lambda c, s: _seed_scan(first, const_vars, seed_keys, c,
                                        out_cap, cfg.impl,
                                        s))(consts, scr)
            ovfs = [bnd.overflow]
            bad = jnp.zeros((), jnp.int32)
            for i, st in enumerate(eff_steps[1:]):
                keys = keys_of(st.patterns[0], bnd.vars)
                out = apply_dist_step(
                    bnd, st, keys, splits_of(st.patterns[0], bnd.vars),
                    cfg, axis, batched=True,
                    fault=fsel[i] if fsel is not None else None,
                    with_check=with_check)
                if with_check:
                    bnd, bad_i = out
                    bad = bad + bad_i
                else:
                    bnd = out
                ovfs.append(bnd.overflow)
            step_ovf = jnp.stack(ovfs)           # (n_steps, batch) cumulative
            return (bnd.table[None], bnd.valid[None], bnd.overflow[None],
                    step_ovf[None], bad[None])

        sharded = shard_map(
            fn, mesh=self.mesh,
            in_specs=(P(axis, None), P(axis, None), P(None, None)),
            out_specs=(P(axis, None, None, None), P(axis, None, None),
                       P(axis, None), P(axis, None, None), P(axis)),
            check_rep=False)
        return jax.jit(sharded), scratch_vars

    def _dispatch(self, tid: int, template: Template, batch: int,
                  consts: np.ndarray, bucket_cap: int, step_caps: tuple,
                  fsel=None, with_check: bool = False):
        """Run one compiled batched cascade; returns per-shard numpy views
        (tables (S, batch, out_cap, nv), valids (S, batch, out_cap),
        overflow (S, batch), step_ovf (S, batch, n_steps) cumulative, and
        the int quarantined-block count `bad`) — S == 1 and bad == 0 on
        the local (mesh-less) path."""
        jitted, scratch_vars = self._compiled_batch(
            tid, template, batch, bucket_cap, step_caps, fsel, with_check)
        # optional jax.profiler bracket: lines the engine dispatch up with
        # XLA's own timeline when the tracer was built with
        # jax_profiler=True; a nullcontext otherwise
        bracket = (self.tracer.jax_bracket(f"serve_dispatch/t{tid}b{batch}")
                   if self.tracer is not None else contextlib.nullcontext())
        if self.mesh is None:
            out_cap = template.steps[0].caps.out_cap
            with bracket:
                out, step_ovf = jitted(self.store.flat_keys(0),
                                       self.store.flat_keys(1),
                                       jnp.asarray(consts),
                                       self._scratch(scratch_vars, batch,
                                                     out_cap))
            return (np.asarray(out.table)[None], np.asarray(out.valid)[None],
                    np.asarray(out.overflow)[None],
                    np.asarray(step_ovf)[None], 0)
        with bracket:
            t, v, o, so, bad = jitted(self.store.keys_spo,
                                      self.store.keys_ops,
                                      jnp.asarray(consts))
        self.a2a_payload_bytes += self._payload_bytes(bucket_cap, step_caps)
        # (S, n_steps, batch) -> (S, batch, n_steps)
        return (np.asarray(t), np.asarray(v), np.asarray(o),
                np.transpose(np.asarray(so), (0, 2, 1)),
                int(np.asarray(bad).sum()))

    def precompile(self, query, batches: Sequence[int] | None = None):
        """Compile (and warm) the query's template cascade for the given
        batch sizes — default every power of two up to max_batch — by
        running it on zeroed constants. A serving deployment calls this
        from a traffic log at startup so no live request ever waits on a
        compile (XLA compiles lazily at first call, so merely building
        the jitted wrapper would not warm anything)."""
        if isinstance(query, str):
            if self.dictionary is None:
                raise ValueError("SPARQL text needs a Dictionary-equipped "
                                 "engine (dictionary=...)")
            query = parse_bgp(query, self.dictionary)
        patterns = tuple(query.patterns if isinstance(query, ParsedQuery)
                         else query)
        plan = self._compile(patterns)
        template, _, _ = plan_signature(self.store, patterns, self.cfg,
                                        self.caps, self.mode, plan=plan)
        tid = self._template_ids.setdefault(template, len(self._template_ids))
        tuned, step_caps = self._plan_caps(plan)
        if batches is None:
            batches = []
            b = 1
            while b <= self.max_batch:
                batches.append(b)
                b <<= 1
        payload0 = self.a2a_payload_bytes
        for b in batches:
            # warm the uniform-batch cap sizes for this query's tuned caps
            fake = [_Request(-1, tid, template, None, (), None, tuned=tuned,
                             step_caps=step_caps) for _ in range(b)]
            self._dispatch(tid, template, b,
                           np.zeros((b, template.n_consts), np.int32),
                           self._bucket_cap_for(fake, b),
                           self._step_caps_for(fake, template))
        self.a2a_payload_bytes = payload0      # warm-up ships no live traffic

    def _scratch(self, scratch_vars: tuple[str, ...], batch: int,
                 out_cap: int | None = None) -> Bindings:
        cap = self.caps.out_cap if out_cap is None else out_cap
        return Bindings(
            scratch_vars,
            jnp.zeros((batch, cap, len(scratch_vars)), jnp.int32),
            jnp.zeros((batch, cap), bool),
            jnp.zeros((batch,), jnp.int32))

    def _exact_fallback(self, r: _Request) -> QueryResult:
        """The escalation chain's guaranteed-exact terminus: run the query
        through the UNRESTRICTED planner (reduce_side available — the
        operator a seeded template cascade cannot express) via
        execute_local, escalating caps until nothing truncates (bounded;
        caps double per try so the bound is generous). Single-store
        execution: exactness beats the batched path's throughput on the
        final attempt."""
        caps = escalate_caps(r.ecaps if r.ecaps is not None else self.caps)
        self.fallbacks += 1
        self.metrics_registry.counter("serve_fallbacks_total").inc()
        log.info("exact_fallback rid=%d after %d escalations", r.rid,
                 r.attempt)
        tr = self.tracer
        fsp = (tr.begin("exact_fallback", track="query", parent=r.span,
                        async_id=r.rid, attempt=r.attempt)
               if tr is not None and r.span is not None else None)
        tries = 0
        step_stats: list | None = None
        for _ in range(8):
            tries += 1
            # traced fallbacks run the instrumented path: per-step wall
            # stamps become cascade_step child spans (tracer clock ==
            # perf_counter, the stats path's stamp clock)
            step_stats = [] if fsp is not None else None
            bnd = execute_local(self.store, r.patterns, self.mode, self.cfg,
                                caps, stats=step_stats)
            if int(bnd.overflow) == 0:
                break
            caps = escalate_caps(caps)
        if fsp is not None:
            spans_from_stats(tr, step_stats, parent=fsp, track="query",
                             async_id=r.rid)
            tr.end(fsp, tries=tries, out_cap=caps.out_cap)
        rows = np.asarray(bnd.table)[np.asarray(bnd.valid)]
        ovf = np.asarray(bnd.step_overflow)
        stats = {"kinds": ("fallback",),
                 "overflow_per_step": tuple(
                     int(x) for x in np.diff(ovf, prepend=0)),
                 "fallback": "reduce_side", "attempt": r.attempt,
                 "caps": caps}
        return QueryResult(r.rid, tuple(bnd.vars), rows, int(bnd.overflow),
                           r.select, stats)

    def _escalate(self, r: _Request, stats: dict) -> None:
        """Re-enqueue an overflowed request at the escalated cap budget:
        replan (new signature/template — escalated plans ride the same
        LRU caches, so a hot heavy-hitter template pays each budget's
        compile once), keep identity/deadline/enq so total latency and
        deadline accounting span all attempts."""
        ecaps = escalate_caps(r.ecaps if r.ecaps is not None else self.caps)
        tid, template, consts, var_order, tuned, step_caps, est_cost = \
            self._signature_for(r.patterns, ecaps)
        self.escalations += 1
        self.metrics_registry.counter("serve_escalations_total").inc()
        log.info("escalate rid=%d attempt=%d out_cap %d -> %d", r.rid,
                 r.attempt + 1,
                 (r.ecaps or self.caps).out_cap, ecaps.out_cap)
        tr = self.tracer
        self._queue.append(dataclasses.replace(
            r, tid=tid, template=template, consts=consts,
            var_order=var_order, tuned=tuned, step_caps=step_caps,
            ecaps=ecaps, attempt=r.attempt + 1, prior_stats=stats,
            est_cost=est_cost, tq0=tr.now() if tr is not None else 0.0))

    def _timeout(self, r: _Request, phase: str, now: float,
                 stats: dict | None = None) -> QueryTimeout:
        self.timeouts += 1
        self.metrics_registry.counter("serve_timeouts_total",
                                      phase=phase).inc()
        log.info("timeout rid=%d phase=%s waited=%.4fs", r.rid, phase,
                 max(now - r.enq, 0.0))
        tr = self.tracer
        if tr is not None and r.span is not None:
            if r.tq0 >= 0:                # still queued: wait span first
                tr.record("queued", r.tq0, tr.now(), track="query",
                          parent=r.span, async_id=r.rid, outcome="timeout",
                          phase=phase)
            tr.end(r.span, outcome="timeout", phase=phase)
            r.span = None
        return QueryTimeout(
            r.rid, r.var_order, np.zeros((0, len(r.var_order)), np.int32),
            0, r.select, stats if stats is not None else r.prior_stats,
            phase=phase, deadline_s=r.deadline or 0.0,
            waited_s=max(now - r.enq, 0.0))

    def _run_bucket(self, reqs: list[_Request],
                    now: float | None = None) -> list[QueryResult]:
        template = reqs[0].template
        n = len(reqs)
        batch = min(_pow2_at_least(n), self.max_batch)
        consts = np.zeros((batch, template.n_consts), np.int32)
        for i, r in enumerate(reqs):
            consts[i] = r.consts
        for i in range(n, batch):                    # padding slots re-run
            consts[i] = reqs[0].consts               # request 0, discarded
        bucket_cap = self._bucket_cap_for(reqs, batch)
        step_caps = self._step_caps_for(reqs, template)
        with_check = self.check_answers and self.mesh is not None
        n_joins = len(template.steps) - 1
        tr = self.tracer
        m = self.metrics_registry
        # per-leg a2a payload of one physical dispatch (distributed.py's
        # wire-format accounting, split probe-out vs answer-back)
        probe_b = answer_b = 0
        if self.mesh is not None and self.cfg.routing == "a2a":
            for cap in step_caps:
                pb, ab = a2a_leg_bytes(bucket_cap, cap,
                                       self.store.num_shards)
                probe_b += pb
                answer_b += ab
        tq = 0.0
        if tr is not None:
            # bulk-materialize the queued-wait spans: ONE clock read and a
            # shared attrs dict per phase — this loop sits on the per-query
            # hot path, whose whole budget is ~2% of service time (§8)
            tq = tr.now()
            q_attrs: dict[str, dict] = {}
            append = tr.spans.append
            for r in reqs:
                if r.span is not None and r.tq0 >= 0:
                    key = "escalation" if r.attempt else "admit"
                    at = q_attrs.get(key)
                    if at is None:
                        at = q_attrs[key] = {"phase": key, "batch": batch}
                    append(Span("queued", r.tq0, tq, "query", at, None,
                                r.span.span_id, r.rid))
                    r.tq0 = -1.0
        t0 = time.monotonic()
        delay = 0.0
        bad = 0
        # fault-detection retry loop: each physical dispatch attempt burns
        # one fault epoch, so a retry naturally escapes a one-shot fault;
        # clean epochs share one compiled cascade (fsel normalized to None)
        for attempt in range(self.fault_retries + 1):
            fsel = None
            epoch = self.fault_epoch
            if self.fault_plan is not None:
                fsel = self.fault_plan.selection(epoch, n_joins)
                delay += self.fault_plan.delay_s_at(epoch)
                if not any(d or c for d, c in fsel):
                    fsel = None
            self.fault_epoch += 1
            dsp = (tr.begin("dispatch", track="engine",
                            parent=self._step_span, template=reqs[0].tid,
                            batch=batch, n=n, epoch=epoch, retry=attempt,
                            faults=fsel is not None, bucket_cap=bucket_cap,
                            probe_bytes=probe_b, answer_bytes=answer_b)
                   if tr is not None else None)
            # (S, batch, out_cap, nv) per-shard tables; S == 1 un-meshed
            tables, valids, overflow, step_ovf, bad = self._dispatch(
                reqs[0].tid, template, batch, consts, bucket_cap,
                step_caps, fsel, with_check)
            if dsp is not None:
                tr.end(dsp, bad=bad)
            if probe_b:
                m.counter("serve_a2a_probe_bytes_total").inc(probe_b)
                m.counter("serve_a2a_answer_bytes_total").inc(answer_b)
            if bad == 0:
                break
            self.corrupt_detected += bad
            m.counter("serve_faults_detected_total").inc(bad)
            log.warning("a2a answer-leg checksum mismatch: %d block(s) "
                        "quarantined (epoch=%d)%s", bad, epoch,
                        "; retrying" if attempt < self.fault_retries
                        else "; retries exhausted")
            if attempt < self.fault_retries:
                self.fault_redispatches += 1
                m.counter("serve_fault_redispatches_total").inc()
        elapsed = (time.monotonic() - t0) + delay
        a = 0.3                                       # service-time EWMA
        self._service_ewma = (elapsed if self._service_ewma == 0.0
                              else a * elapsed + (1 - a) * self._service_ewma)
        end_clock = (now if now is not None else t0) + elapsed
        watchdog = (self.dispatch_timeout_s is not None
                    and elapsed > self.dispatch_timeout_s)
        nk = template.n_consts
        kinds = tuple(st.kind for st in template.steps)
        self.dispatches += 1
        self.dispatched_queries += n
        tnow = time.monotonic()
        if self._t_first_dispatch is None:
            self._t_first_dispatch = tnow - elapsed
        self._t_last_dispatch = tnow
        self._m_dispatches.inc()
        self._m_disp_queries.inc(n)
        self._m_batch_hist.observe(n)
        if bad > 0:
            m.counter("serve_fault_unrecovered_total").inc()
        # delivery: rung + root spans materialize HERE, one shared `td`
        # clock read and one shared attrs dict per (attempt, outcome) —
        # nothing span-shaped is allocated per query before this point
        td = tr.now() if tr is not None else 0.0
        r_shared: dict = {}
        results = []
        for i, r in enumerate(reqs):
            # cumulative per-step counters summed over shards -> deltas:
            # which step dropped rows (probe vs out-cap truncation locale)
            cum = step_ovf[:, i, :].sum(axis=0)
            per_step = tuple(int(x) for x in np.diff(cum, prepend=0))
            stats = {"kinds": kinds, "overflow_per_step": per_step,
                     "attempt": r.attempt}
            if bad > 0:
                stats["fault_unrecovered"] = True
            deadline_ok = (r.deadline is None
                           or (now is None and r.arrival is not None))
            if watchdog or (not deadline_ok and end_clock > r.deadline):
                # a dispatch that finishes past the deadline (or trips the
                # engine watchdog) is SHED — never a truncated row set
                # delivered as if complete
                if tr is not None and r.span is not None:
                    tr.spans.append(Span(
                        _rung_name(r.attempt), tq, td, "query",
                        {"attempt": r.attempt, "outcome": "timeout",
                         "batch": batch, "bucket_cap": bucket_cap},
                        None, r.span.span_id, r.rid))
                results.append(self._timeout(r, "dispatch", end_clock,
                                             stats))
                continue
            ovf = int(overflow[:, i].sum())
            if ovf > 0:
                m.counter("serve_overflow_rows_total").inc(ovf)
            if (ovf > 0 and not r.inexact_ok and self.max_escalations > 0
                    and r.patterns is not None and bad == 0):
                if r.attempt + 1 >= self.max_escalations:
                    if tr is not None and r.span is not None:
                        tr.spans.append(Span(
                            _rung_name(r.attempt), tq, td, "query",
                            {"attempt": r.attempt, "outcome": "fallback",
                             "overflow": ovf, "batch": batch,
                             "out_cap": (r.ecaps or self.caps).out_cap,
                             "bucket_cap": bucket_cap},
                            None, r.span.span_id, r.rid))
                    res = self._exact_fallback(r)
                    results.append(res)
                    if tr is not None and r.span is not None:
                        tr.end(r.span, outcome="ok", fallback=True,
                               rows=len(res.rows))
                        r.span = None
                else:
                    if tr is not None and r.span is not None:
                        tr.spans.append(Span(
                            _rung_name(r.attempt), tq, td, "query",
                            {"attempt": r.attempt, "outcome": "escalate",
                             "overflow": ovf, "batch": batch,
                             "out_cap": (r.ecaps or self.caps).out_cap,
                             "bucket_cap": bucket_cap},
                            None, r.span.span_id, r.rid))
                    self._escalate(r, stats)
                continue
            if ovf > 0 and r.inexact_ok:
                stats["degraded"] = True     # bounded-inexact, by request
            rows = np.concatenate([tables[s, i][valids[s, i]]
                                   for s in range(tables.shape[0])]
                                  )[:, nk:nk + len(r.var_order)]
            results.append(QueryResult(r.rid, r.var_order, rows, ovf,
                                       r.select, stats))
            outcome = "degraded" if stats.get("degraded") else "ok"
            root = r.span
            if tr is not None and root is not None:
                # rung spans mark the ABNORMAL ladder (escalated attempts,
                # degraded serves); a first-attempt clean query is fully
                # told by queued + root + the engine dispatch span, and
                # that hot path skips the extra allocation
                if r.attempt or outcome != "ok":
                    at = r_shared.get((r.attempt, outcome))
                    if at is None:
                        at = r_shared[(r.attempt, outcome)] = {
                            "attempt": r.attempt, "outcome": outcome,
                            "batch": batch, "bucket_cap": bucket_cap,
                            "out_cap": (r.ecaps or self.caps).out_cap}
                    tr.spans.append(Span(_rung_name(r.attempt), tq, td,
                                         "query", at, None, root.span_id,
                                         r.rid))
                # inline tr.end(root): skips the open-table membership
                # check and a second clock read on the hottest path
                del tr._open[root.span_id]
                root.t1 = td
                root.attrs["outcome"] = outcome
                root.attrs["rows"] = len(rows)
                if ovf:
                    root.attrs["overflow"] = ovf
                tr.spans.append(root)
                r.span = None
            # per-template / per-tenant latency SLO histograms — only
            # when enqueue and completion live on the same clock domain
            # (both harness-stamped or both monotonic)
            if (r.arrival is not None) == (now is not None):
                lat = max(end_clock - r.enq, 0.0)
                h = self._m_tpl_hist.get(r.tid)
                if h is None:
                    h = self._m_tpl_hist[r.tid] = m.histogram(
                        "serve_template_latency_seconds",
                        template=f"{self.name}:t{r.tid}")
                h.observe(lat)
                h = self._m_ten_hist.get(r.tenant)
                if h is None:
                    h = self._m_ten_hist[r.tenant] = m.histogram(
                        "serve_tenant_latency_seconds",
                        tenant=str(r.tenant))
                h.observe(lat)
        return results

    # --- scheduling ------------------------------------------------------

    def step(self, now: float | None = None,
             force: bool = False) -> list[QueryResult]:
        """Dispatch the fullest template bucket (at most max_batch
        requests) as one batched cascade; [] when the queue is empty.

        Dispatch policy (min_batch/max_wait_s): when the fullest bucket
        is below `min_batch`, the dispatch is DEFERRED (returns [] with
        requests still pending) so capacity near saturation is not burned
        on tiny batches — UNLESS the oldest queued request has already
        waited `max_wait_s` on the `now` clock (arrival-stamped requests
        use the harness clock, others time.monotonic), in which case its
        bucket dispatches as-is: the aging override bounds worst-case
        queueing latency at max_wait_s + one dispatch. `force=True`
        (drain) bypasses the policy. The defaults (min_batch=1) keep the
        greedy always-dispatch behavior.

        Anti-starvation aging: fullest-first alone would let a steady
        majority template starve a minority request forever. After the
        oldest queued request's bucket has been passed over
        `starvation_limit` consecutive steps, its bucket dispatches
        next regardless of size — latency is bounded by
        starvation_limit dispatches, throughput stays batch-greedy.

        Deadline sweep (DESIGN.md §7): before picking a bucket, every
        queued request whose absolute deadline has passed on the `now`
        clock is shed with a QueryTimeout (phase "queued", or
        "escalation" for an overflow-escalation retry) — expired queries
        never occupy batch slots. Results evicted by priority shedding
        (QueryShed) are delivered here too."""
        tr = self.tracer
        m = self.metrics_registry
        if tr is None:
            out = self._step(now, force)
        else:
            sp = self._step_span = tr.begin("step", track="engine")
            try:
                out = self._step(now, force)
            except Exception:
                tr.end(sp, outcome="error")
                raise
            finally:
                self._step_span = None
            tr.end(sp, delivered=len(out), queue=len(self._queue))
        self._m_depth.set(len(self._queue))
        m.tick()
        return out

    def _step(self, now: float | None = None,
              force: bool = False) -> list[QueryResult]:
        out: list[QueryResult] = list(self._shed)
        self._shed.clear()
        if not self._queue:
            return out
        clock = now if now is not None else time.monotonic()
        # clock-domain guard: arrival-stamped requests live on the harness
        # clock — only an explicit `now` can expire them (monotonic time
        # would instantly blow every replayed deadline)
        expired = [r for r in self._queue
                   if r.deadline is not None and clock >= r.deadline
                   and (now is not None or r.arrival is None)]
        if expired:
            gone = {r.rid for r in expired}
            self._queue = deque(r for r in self._queue
                                if r.rid not in gone)
            out.extend(self._timeout(
                r, "escalation" if r.attempt > 0 else "queued", clock)
                for r in expired)
            if not self._queue:
                return out
        buckets: dict[int, list[_Request]] = {}
        for r in self._queue:
            buckets.setdefault(r.tid, []).append(r)
        head_tid = self._queue[0].tid
        if self._head_skips >= self.starvation_limit:
            pick = buckets[head_tid]
        else:
            # fullest bucket first; FIFO within a bucket (deque order)
            pick = max(buckets.values(), key=len)
        if not force and len(pick) < self.min_batch:
            if clock - self._queue[0].enq < self.max_wait_s:
                return out                # defer: let the batch fill
            pick = buckets[head_tid]      # aged past max_wait_s: serve the
                                          # oldest request's bucket as-is
        chosen = pick[:self.max_batch]
        if chosen[0].tid == head_tid:
            self._head_skips = 0
        else:
            self._head_skips += 1
        taken = {r.rid for r in chosen}
        self._queue = deque(r for r in self._queue if r.rid not in taken)
        out.extend(self._run_bucket(chosen, now=now))
        return out

    def drain(self) -> list[QueryResult]:
        out: list[QueryResult] = []
        while self._queue or self._shed:
            out.extend(self.step(force=True))
        return out

    def execute(self, queries) -> list[QueryResult]:
        """Submit + drain a closed batch, results in input order."""
        rids = [self.submit(q) for q in queries]
        by_rid = {res.request_id: res for res in self.drain()}
        return [by_rid[rid] for rid in rids]
