"""Quickstart: build an RDF store, run a SPARQL BGP with the MAPSIN join.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (Caps, Dictionary, build_store, execute_local,
                        query_traffic, rows_set)

# --- the paper's running example (Section 2.1 RDF graph) -------------------
d = Dictionary()
triples = d.encode_triples([
    ("Article1", "title", "PigSPARQL"),
    ("Article1", "year", "2011"),
    ("Article1", "author", "Alex"),
    ("Article1", "author", "Martin"),
    ("Article2", "title", "RDFPath"),
    ("Article2", "year", "2011"),
    ("Article2", "author", "Martin"),
    ("Article2", "author", "Alex"),
    ("Article2", "cite", "Article1"),
])
store = build_store(triples, num_shards=1)

# --- Query 1 from the paper: title + author + year of every article --------
query = [
    d.pattern("?article", "title", "?title"),
    d.pattern("?article", "author", "?author"),
    d.pattern("?article", "year", "?year"),
]
caps = Caps(out_cap=1024, probe_cap=8, row_cap=16)
result = execute_local(store, query, mode="mapsin", caps=caps)
rows = rows_set(result.table, result.valid, len(result.vars))
print("vars:", result.vars)
for row in sorted(rows):
    print("  ", tuple(d.term(v) for v in row))

# --- the paper's network argument, in bytes (10-shard cluster model) --------
for mode in ("mapsin_routed", "mapsin", "reduce"):
    print(f"{mode:15s} modeled interconnect bytes: "
          f"{query_traffic(query, mode, caps, num_shards=10, store=store):,}")
