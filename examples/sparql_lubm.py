"""End-to-end driver (the paper's kind of workload = query serving):
generate a LUBM-like dataset, pose the paper's benchmark queries AS
SPARQL TEXT through the serve front-end (serve/sparql.py), execute with
both engines, verify against the oracle, print the comparison table.

    PYTHONPATH=src python examples/sparql_lubm.py [n_universities]
    PYTHONPATH=src python examples/sparql_lubm.py 1 --sparql \\
        'SELECT ?x WHERE { ?x a <Professor> . ?x <worksFor> <Dept0.U0> . }'
    PYTHONPATH=src python examples/sparql_lubm.py 1 --explain [--sparql Q]

With --sparql the given query (text or a path to a .rq/.sparql file) is
parsed, executed, and its rows printed with dictionary-decoded terms.
With --explain NOTHING executes: the compiled ``PhysicalPlan`` (cost-based
join order, per-step operator, caps, cost estimates) is printed for the
ad-hoc --sparql query, or for every built-in query when --sparql is
absent. Without either flag, every built-in query runs from its text form
in data/rdf_gen.py:LUBM_SPARQL — the front-end is on the path, not beside
it (each parse is also asserted equal to the hand-built Pattern list).
"""
import os
import sys
import time

import jax

from repro.core import (Caps, build_store, compile_plan, execute_local,
                        execute_oracle, explain, query_traffic, rows_set)
from repro.data import lubm_like
from repro.data.rdf_gen import LUBM_SPARQL
from repro.serve import parse_bgp

args = sys.argv[1:]
explain_only = "--explain" in args
if explain_only:
    args.remove("--explain")
sparql_text = None
if "--sparql" in args:
    i = args.index("--sparql")
    if i + 1 >= len(args):
        sys.exit("usage: sparql_lubm.py [n_universities] [--explain] "
                 "[--sparql QUERY_TEXT_OR_FILE]")
    sparql_text = args[i + 1]
    args = args[:i] + args[i + 2:]
    if os.path.exists(sparql_text):
        with open(sparql_text) as f:
            sparql_text = f.read()
n_univ = int(args[0]) if args else 1

triples, d, hand_built = lubm_like(n_univ)
print(f"LUBM-like x{n_univ}: {len(triples):,} triples, {len(d):,} terms")
store = build_store(triples, num_shards=1)
# probe_cap must hold Q8's memberOf fan-out (120 students per department);
# at 16 the probe truncates (surfaced as overflow) and Q8 reported inexact
caps = Caps(scan_cap=1 << 16, out_cap=1 << 16, probe_cap=128, row_cap=64)

if explain_only:
    # print the physical plan(s), execute nothing
    if sparql_text is not None:
        queries = {"ad-hoc": list(parse_bgp(sparql_text, d).patterns)}
    else:
        queries = {name: list(parse_bgp(text, d).patterns)
                   for name, text in LUBM_SPARQL.items()}
    for name, pats in queries.items():
        plan = compile_plan(store, pats, caps)
        print(f"\n== {name} ==")
        print(explain(plan, decode=d.term))
    sys.exit(0)

if sparql_text is not None:
    pq = parse_bgp(sparql_text, d)           # ValueError on bad input
    bnd = execute_local(store, list(pq.patterns), "mapsin", caps=caps)
    got = rows_set(bnd.table, bnd.valid, len(bnd.vars))
    sel = [bnd.vars.index(v) for v in pq.select]
    print("  ".join(pq.select))
    for row in sorted(got):
        print("  ".join(d.term(row[i]) for i in sel))
    print(f"-- {len(got)} rows, overflow={int(bnd.overflow)}")
    sys.exit(0)

print(f"{'query':6s} {'rows':>6s} {'mapsin':>9s} {'reduce':>9s} "
      f"{'speedup':>8s} {'net-ratio':>9s}  exact")
for qname, text in LUBM_SPARQL.items():
    pats = list(parse_bgp(text, d).patterns)     # the front-end is the path
    assert pats == hand_built[qname], f"{qname}: text form drifted"
    times = {}
    for mode in ("mapsin", "reduce"):
        fn = lambda m=mode: execute_local(store, pats, m, caps=caps)
        fn()  # compile
        t0 = time.perf_counter()
        bnd = fn()
        jax.block_until_ready(bnd.table)
        times[mode] = time.perf_counter() - t0
    got = rows_set(bnd.table, bnd.valid, len(bnd.vars))
    want, ovars = execute_oracle(triples, pats)
    if tuple(bnd.vars) != ovars:
        perm = [bnd.vars.index(v) for v in ovars]
        got = set(tuple(r[i] for i in perm) for r in got)
    net = (query_traffic(pats, "reduce", caps, 10, store=store)
           / max(query_traffic(pats, "mapsin_routed", caps, 10,
                               store=store), 1))
    print(f"{qname:6s} {len(got):6d} {times['mapsin']*1e3:8.1f}m "
          f"{times['reduce']*1e3:8.1f}m {times['reduce']/times['mapsin']:8.2f} "
          f"{net:9.1f}  {got == want}")
