"""End-to-end driver (the paper's kind of workload = query serving):
generate a LUBM-like dataset, execute the paper's benchmark queries with
both engines, verify against the oracle, print the comparison table.

    PYTHONPATH=src python examples/sparql_lubm.py [n_universities]
"""
import sys
import time

import jax

from repro.core import (ExecConfig, build_store, execute_local,
                        execute_oracle, query_traffic, rows_set)
from repro.data import lubm_like

n_univ = int(sys.argv[1]) if len(sys.argv) > 1 else 1
triples, d, queries = lubm_like(n_univ)
print(f"LUBM-like x{n_univ}: {len(triples):,} triples, {len(d):,} terms")
store = build_store(triples, num_shards=1)
cfg = ExecConfig(scan_cap=1 << 16, out_cap=1 << 16, probe_cap=16, row_cap=64)

print(f"{'query':6s} {'rows':>6s} {'mapsin':>9s} {'reduce':>9s} "
      f"{'speedup':>8s} {'net-ratio':>9s}  exact")
for qname, pats in queries.items():
    times = {}
    for mode in ("mapsin", "reduce"):
        fn = lambda m=mode: execute_local(store, pats, m, cfg)
        fn()  # compile
        t0 = time.perf_counter()
        bnd = fn()
        jax.block_until_ready(bnd.table)
        times[mode] = time.perf_counter() - t0
    got = rows_set(bnd.table, bnd.valid, len(bnd.vars))
    want, ovars = execute_oracle(triples, pats)
    if tuple(bnd.vars) != ovars:
        perm = [bnd.vars.index(v) for v in ovars]
        got = set(tuple(r[i] for i in perm) for r in got)
    net = (query_traffic(pats, "reduce", cfg, 10)
           / max(query_traffic(pats, "mapsin_routed", cfg, 10), 1))
    print(f"{qname:6s} {len(got):6d} {times['mapsin']*1e3:8.1f}m "
          f"{times['reduce']*1e3:8.1f}m {times['reduce']/times['mapsin']:8.2f} "
          f"{net:9.1f}  {got == want}")
