"""Train a small LM end-to-end with the fault-tolerant runtime.

    PYTHONPATH=src python examples/train_lm.py [--arch xlstm-125m] [--steps 30]

Uses the reduced config on CPU; on a pod, drop --smoke semantics by editing
shape/config (launch/train.py exposes the full path).
"""
import argparse
import tempfile

from repro.configs import get_config, reduce_for_smoke
from repro.configs.base import ShapeConfig
from repro.optim import OptConfig
from repro.runtime import Trainer

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="xlstm-125m")
ap.add_argument("--steps", type=int, default=30)
args = ap.parse_args()

cfg = reduce_for_smoke(get_config(args.arch))
shape = ShapeConfig("example", 128, 4, "train")
with tempfile.TemporaryDirectory() as workdir:
    trainer = Trainer(cfg, shape, workdir, OptConfig(warmup_steps=5),
                      ckpt_every=10)
    losses = []
    trainer.run(args.steps, hook=lambda s, m: losses.append(float(m["loss"])))
    print(f"arch={args.arch} steps={args.steps} "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "loss should decrease"
    print("checkpoints + straggler watchdog exercised; resume is bit-exact "
          "(see tests/test_checkpoint_optim_data.py)")
