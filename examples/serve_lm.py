"""Serve a small model: batched prefill + incremental decode with KV cache.

    PYTHONPATH=src python examples/serve_lm.py [--arch qwen3-8b]
"""
import argparse

from repro.launch import serve  # reuse the CLI implementation
import sys

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen3-8b")
args, rest = ap.parse_known_args()
sys.argv = ["serve", "--arch", args.arch, "--smoke", "--tokens", "8"] + rest
from repro.launch.serve import main
main()
